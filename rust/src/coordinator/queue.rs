//! The Falkon wait queue (Q in §3.2).
//!
//! The data-aware scheduler's second phase considers a *window* of up to W
//! tasks from the head of the queue and removes arbitrary tasks in the
//! window (those with the best cache-hit scores). A `VecDeque` would make
//! those removals O(W); this queue is an arena of slots threaded with an
//! intrusive doubly-linked list, giving O(1) push/pop/mid-removal and
//! cache-friendly in-order traversal.
//!
//! Two features support the **sub-linear indexed pickup** (§Perf
//! iteration 3; see [`crate::coordinator::pending`]):
//!
//! * every queued task carries a monotonically increasing **sequence
//!   number** ([`WaitQueue::seq_of`]). Tasks are only ever appended at
//!   the tail, so queue order and sequence order coincide forever —
//!   "is task A ahead of task B?" is an integer comparison, with no
//!   pointer chasing;
//! * a lazily maintained **window-boundary cursor**
//!   ([`WaitQueue::window_boundary_seq`]) tracks the slot at rank W, so
//!   "is this task inside the current window?" is `seq < boundary` —
//!   O(1) per query, amortized O(1) maintenance per queue op (the
//!   boundary rank shifts by at most one per push/removal).
//!
//! Together these let the scheduler test window membership of an indexed
//! candidate without walking the list — the property the sub-linear
//! pickup-cost argument depends on.

use crate::ids::{FileId, TaskId};
use crate::util::time::Micros;

/// A task (κ ∈ K) as the coordinator sees it.
#[derive(Debug, Clone)]
pub struct Task {
    /// Task id (position in the incoming stream).
    pub id: TaskId,
    /// Data objects the task reads — θ(κ). Usually one file in the
    /// paper's workloads, but the scheduler handles any number.
    pub files: Vec<FileId>,
    /// Compute duration μ(κ).
    pub compute: Micros,
    /// Submission time (for response-time metrics).
    pub arrival: Micros,
}

/// Stable reference to a queued task (valid until removed).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QueueRef(u32);

/// Cost counters for the window-boundary cursor
/// ([`WaitQueue::window_boundary_seq`]). The sub-linear pickup argument
/// rests on the cursor being amortized-O(1): cold seeks should be rare
/// (cursor invalidation only) and amortized steps should stay ~O(1) per
/// query. `perf_hotpath` surfaces these so the CI bench gate can watch
/// regressions in the amortization.
#[derive(Debug, Default, Clone)]
pub struct BoundaryStats {
    /// Boundary queries answered (including trivial whole-queue cases).
    pub queries: u64,
    /// Queries that had to seek the cursor from a list end.
    pub cold_seeks: u64,
    /// Link-walk steps spent in cold seeks.
    pub cold_seek_steps: u64,
    /// Link-walk steps spent re-positioning a warm cursor.
    pub amortized_steps: u64,
}

impl BoundaryStats {
    /// Mean warm-cursor steps per query (the amortization headline).
    pub fn amortized_steps_per_query(&self) -> f64 {
        self.amortized_steps as f64 / self.queries.max(1) as f64
    }
}

const NIL: u32 = u32::MAX;

#[derive(Debug)]
struct Slot {
    task: Option<Task>,
    /// Queue sequence number of the occupying task (stale after removal
    /// until the slot is reused; only read while occupied).
    seq: u64,
    prev: u32,
    next: u32,
}

/// FIFO wait queue with O(1) mid-queue removal and O(1) window-membership
/// tests.
#[derive(Debug)]
pub struct WaitQueue {
    slots: Vec<Slot>,
    free: Vec<u32>,
    head: u32,
    tail: u32,
    len: usize,
    /// Next sequence number to assign (monotonic; never reused).
    next_seq: u64,
    /// Window-boundary cursor slot (NIL = not currently tracked).
    cursor: u32,
    /// 0-based rank of `cursor` when it is not NIL.
    cursor_rank: usize,
    /// High-water mark (the paper reports 7K–200K peak queue lengths).
    pub max_len: usize,
    /// Boundary-cursor cost counters (§Perf scheduler stats).
    pub boundary_stats: BoundaryStats,
}

impl Default for WaitQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl WaitQueue {
    /// Empty queue.
    pub fn new() -> Self {
        WaitQueue {
            slots: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            len: 0,
            next_seq: 0,
            cursor: NIL,
            cursor_rank: 0,
            max_len: 0,
            boundary_stats: BoundaryStats::default(),
        }
    }

    /// Number of queued tasks.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no tasks are waiting.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append a task at the tail; returns its stable reference.
    pub fn push_back(&mut self, task: Task) -> QueueRef {
        let seq = self.next_seq;
        self.next_seq += 1;
        let idx = match self.free.pop() {
            Some(i) => {
                self.slots[i as usize] = Slot {
                    task: Some(task),
                    seq,
                    prev: self.tail,
                    next: NIL,
                };
                i
            }
            None => {
                self.slots.push(Slot {
                    task: Some(task),
                    seq,
                    prev: self.tail,
                    next: NIL,
                });
                (self.slots.len() - 1) as u32
            }
        };
        if self.tail != NIL {
            self.slots[self.tail as usize].next = idx;
        } else {
            self.head = idx;
        }
        self.tail = idx;
        self.len += 1;
        self.max_len = self.max_len.max(self.len);
        // The new task has the largest seq: every tracked rank < len-1 is
        // unaffected, so the cursor stays valid as-is.
        QueueRef(idx)
    }

    /// Peek the head task (T₀) without removing it.
    pub fn front(&self) -> Option<&Task> {
        if self.head == NIL {
            None
        } else {
            self.slots[self.head as usize].task.as_ref()
        }
    }

    /// Reference to the head slot.
    pub fn front_ref(&self) -> Option<QueueRef> {
        if self.head == NIL {
            None
        } else {
            Some(QueueRef(self.head))
        }
    }

    /// Remove and return the head task.
    pub fn pop_front(&mut self) -> Option<Task> {
        self.front_ref().map(|r| self.remove(r))
    }

    /// Remove an arbitrary queued task by reference.
    ///
    /// Panics if the reference was already removed (references are not
    /// reused until then, so a stale ref is a logic bug upstream).
    pub fn remove(&mut self, qref: QueueRef) -> Task {
        let idx = qref.0;
        // Maintain the boundary cursor before unlinking: removing the
        // cursor slot shifts the cursor to its successor (same rank);
        // removing anything *ahead* of the cursor lowers its rank by one.
        if self.cursor != NIL {
            if self.cursor == idx {
                self.cursor = self.slots[idx as usize].next;
                // rank unchanged: the successor inherits the removed rank
                // (cursor may become NIL when removing the tail).
            } else if self.slots[idx as usize].seq < self.slots[self.cursor as usize].seq {
                self.cursor_rank -= 1;
            }
        }
        let (prev, next, task) = {
            let slot = &mut self.slots[idx as usize];
            let task = slot.task.take().expect("QueueRef already removed");
            (slot.prev, slot.next, task)
        };
        if prev != NIL {
            self.slots[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
        self.free.push(idx);
        self.len -= 1;
        task
    }

    /// Access a queued task by reference.
    pub fn get(&self, qref: QueueRef) -> &Task {
        self.slots[qref.0 as usize]
            .task
            .as_ref()
            .expect("QueueRef already removed")
    }

    /// Sequence number of the task currently occupying `qref`, or `None`
    /// when the slot is empty (the task was removed). This is the O(1)
    /// liveness probe for **lazily maintained candidate hints**
    /// ([`crate::coordinator::pending`]): a hint `(seq, qref)` refers to a
    /// still-queued task **iff** `live_seq(qref) == Some(seq)` — slots are
    /// reused but sequence numbers never are, so a reused slot can never
    /// alias an old hint.
    pub fn live_seq(&self, qref: QueueRef) -> Option<u64> {
        let slot = &self.slots[qref.0 as usize];
        if slot.task.is_some() {
            Some(slot.seq)
        } else {
            None
        }
    }

    /// Sequence number of a queued task. Sequence order equals queue
    /// order (tasks only enter at the tail), so two tasks' relative queue
    /// positions compare as integers.
    pub fn seq_of(&self, qref: QueueRef) -> u64 {
        let slot = &self.slots[qref.0 as usize];
        debug_assert!(slot.task.is_some(), "seq_of on removed QueueRef");
        slot.seq
    }

    /// Exclusive upper sequence bound of the scheduling window of size
    /// `window`: a queued task is inside the window **iff** its seq is
    /// `< bound`. Returns `None` when the whole queue fits in the window
    /// (every queued task is eligible).
    ///
    /// Amortized O(1): the boundary slot (rank `window`) is tracked by a
    /// cursor that each push/removal shifts by at most one position, so
    /// consecutive calls with a stable window size only walk the few
    /// links the queue churned since the last call. A cold cursor (or a
    /// resized cluster changing W) pays one O(min(W, |Q|−W)) seek.
    pub fn window_boundary_seq(&mut self, window: usize) -> Option<u64> {
        self.boundary_stats.queries += 1;
        if self.len <= window {
            return None;
        }
        // Target rank `window` exists: 1 ≤ window < len.
        let target = window;
        if self.cursor == NIL {
            // Cold seek from whichever end is closer.
            let from_head = target;
            let from_tail = self.len - 1 - target;
            self.boundary_stats.cold_seeks += 1;
            self.boundary_stats.cold_seek_steps += from_head.min(from_tail) as u64;
            if from_head <= from_tail {
                let mut slot = self.head;
                for _ in 0..from_head {
                    slot = self.slots[slot as usize].next;
                }
                self.cursor = slot;
            } else {
                let mut slot = self.tail;
                for _ in 0..from_tail {
                    slot = self.slots[slot as usize].prev;
                }
                self.cursor = slot;
            }
            self.cursor_rank = target;
        } else {
            while self.cursor_rank < target {
                self.cursor = self.slots[self.cursor as usize].next;
                self.cursor_rank += 1;
                self.boundary_stats.amortized_steps += 1;
                debug_assert!(self.cursor != NIL, "rank < len implies a successor");
            }
            while self.cursor_rank > target {
                self.cursor = self.slots[self.cursor as usize].prev;
                self.cursor_rank -= 1;
                self.boundary_stats.amortized_steps += 1;
                debug_assert!(self.cursor != NIL, "rank ≥ 0 implies a predecessor");
            }
        }
        debug_assert!(
            self.slots[self.cursor as usize].task.is_some(),
            "boundary cursor must point at an occupied slot"
        );
        Some(self.slots[self.cursor as usize].seq)
    }

    /// Iterate `(QueueRef, &Task)` head→tail, up to `window` entries —
    /// the scheduling-window scan of §3.2. O(min(|Q|, window)). Retained
    /// for the reference scheduler, zero-hit fallback scans, and tests;
    /// the indexed pickup path avoids it entirely.
    pub fn window(&self, window: usize) -> WindowIter<'_> {
        WindowIter {
            queue: self,
            cursor: self.head,
            remaining: window,
        }
    }
}

/// Iterator over the scheduling window.
pub struct WindowIter<'a> {
    queue: &'a WaitQueue,
    cursor: u32,
    remaining: usize,
}

impl<'a> Iterator for WindowIter<'a> {
    type Item = (QueueRef, &'a Task);

    fn next(&mut self) -> Option<Self::Item> {
        if self.remaining == 0 || self.cursor == NIL {
            return None;
        }
        let idx = self.cursor;
        let slot = &self.queue.slots[idx as usize];
        self.cursor = slot.next;
        self.remaining -= 1;
        Some((
            QueueRef(idx),
            slot.task.as_ref().expect("linked slot must be occupied"),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(i: u64) -> Task {
        Task {
            id: TaskId(i),
            files: vec![FileId(i as u32)],
            compute: Micros::from_millis(10),
            arrival: Micros::ZERO,
        }
    }

    #[test]
    fn fifo_order() {
        let mut q = WaitQueue::new();
        for i in 0..5 {
            q.push_back(task(i));
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop_front().unwrap().id, TaskId(i));
        }
        assert!(q.is_empty());
        assert_eq!(q.max_len, 5);
    }

    #[test]
    fn mid_removal_keeps_order() {
        let mut q = WaitQueue::new();
        let refs: Vec<_> = (0..5).map(|i| q.push_back(task(i))).collect();
        assert_eq!(q.remove(refs[2]).id, TaskId(2));
        assert_eq!(q.remove(refs[0]).id, TaskId(0));
        let order: Vec<_> = q.window(10).map(|(_, t)| t.id.0).collect();
        assert_eq!(order, vec![1, 3, 4]);
        assert_eq!(q.remove(refs[4]).id, TaskId(4));
        let order: Vec<_> = q.window(10).map(|(_, t)| t.id.0).collect();
        assert_eq!(order, vec![1, 3]);
    }

    #[test]
    fn window_is_bounded() {
        let mut q = WaitQueue::new();
        for i in 0..100 {
            q.push_back(task(i));
        }
        assert_eq!(q.window(7).count(), 7);
        assert_eq!(q.window(1000).count(), 100);
    }

    #[test]
    fn seq_is_monotone_in_queue_order() {
        let mut q = WaitQueue::new();
        let refs: Vec<_> = (0..10).map(|i| q.push_back(task(i))).collect();
        q.remove(refs[3]);
        q.remove(refs[7]);
        q.push_back(task(10)); // reuses a slot; seq must still be largest
        let seqs: Vec<u64> = q.window(usize::MAX).map(|(r, _)| q.seq_of(r)).collect();
        for w in seqs.windows(2) {
            assert!(w[0] < w[1], "seqs out of order: {seqs:?}");
        }
    }

    #[test]
    fn boundary_matches_naive_rank() {
        let mut q = WaitQueue::new();
        for i in 0..20 {
            q.push_back(task(i));
        }
        // Whole queue inside the window.
        assert_eq!(q.window_boundary_seq(20), None);
        assert_eq!(q.window_boundary_seq(100), None);
        // Boundary = seq of the task at rank w: members are ranks 0..w-1.
        for w in [1usize, 5, 19] {
            let bound = q.window_boundary_seq(w).expect("len > w");
            let in_window: Vec<u64> = q
                .window(usize::MAX)
                .filter(|&(r, _)| q.seq_of(r) < bound)
                .map(|(_, t)| t.id.0)
                .collect();
            let naive: Vec<u64> = q.window(w).map(|(_, t)| t.id.0).collect();
            assert_eq!(in_window, naive, "window {w}");
        }
    }

    #[test]
    fn boundary_tracks_random_churn() {
        use crate::util::proptest::{property, Gen};
        property("window boundary cursor", 100, |g: &mut Gen| {
            let mut q = WaitQueue::new();
            let mut live: Vec<QueueRef> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(1..300) {
                match g.usize_in(0..5) {
                    0 | 1 | 2 => {
                        let r = q.push_back(task(next_id));
                        live.push(r);
                        next_id += 1;
                    }
                    3 if !live.is_empty() => {
                        let i = g.usize_in(0..live.len());
                        let r = live.swap_remove(i);
                        q.remove(r);
                    }
                    _ => {}
                }
                // Random window sizes, including degenerate ones.
                let w = g.usize_in(1..12);
                let bound = q.window_boundary_seq(w);
                let expect: Vec<u64> = q.window(w).map(|(_, t)| t.id.0).collect();
                let got: Vec<u64> = q
                    .window(usize::MAX)
                    .filter(|&(r, _)| bound.is_none_or(|b| q.seq_of(r) < b))
                    .map(|(_, t)| t.id.0)
                    .collect();
                if got != expect {
                    return Err(format!("w={w}: {got:?} != {expect:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn boundary_stats_count_cold_and_amortized() {
        let mut q = WaitQueue::new();
        for i in 0..100 {
            q.push_back(task(i));
        }
        let _ = q.window_boundary_seq(10); // cold seek from the head side
        assert_eq!(q.boundary_stats.cold_seeks, 1);
        assert_eq!(q.boundary_stats.cold_seek_steps, 10);
        let _ = q.window_boundary_seq(10); // warm, cursor already in place
        assert_eq!(q.boundary_stats.amortized_steps, 0);
        q.pop_front(); // shifts the tracked rank by one
        let _ = q.window_boundary_seq(10);
        assert_eq!(q.boundary_stats.cold_seeks, 1);
        assert_eq!(q.boundary_stats.amortized_steps, 1);
        assert_eq!(q.boundary_stats.queries, 3);
        assert!(q.boundary_stats.amortized_steps_per_query() < 1.0);
    }

    #[test]
    fn slot_reuse_after_removal() {
        let mut q = WaitQueue::new();
        let r = q.push_back(task(1));
        q.remove(r);
        let r2 = q.push_back(task(2));
        assert_eq!(q.get(r2).id, TaskId(2));
        assert_eq!(q.len(), 1);
        // Arena should not have grown.
        assert_eq!(q.slots.len(), 1);
    }

    #[test]
    fn live_seq_detects_removal_and_slot_reuse() {
        let mut q = WaitQueue::new();
        let r = q.push_back(task(1));
        let seq = q.seq_of(r);
        assert_eq!(q.live_seq(r), Some(seq));
        q.remove(r);
        assert_eq!(q.live_seq(r), None);
        // The slot is reused, but with a fresh (never-reused) seq: an old
        // (seq, qref) hint can never validate against the new occupant.
        let r2 = q.push_back(task(2));
        assert_eq!(r2, r, "slot must be recycled for this test");
        assert_ne!(q.live_seq(r2), Some(seq));
        assert_eq!(q.live_seq(r2), Some(q.seq_of(r2)));
    }

    #[test]
    #[should_panic(expected = "QueueRef already removed")]
    fn stale_ref_panics() {
        let mut q = WaitQueue::new();
        let r = q.push_back(task(1));
        q.remove(r);
        let _ = q.get(r);
    }

    #[test]
    fn random_ops_preserve_linkage() {
        use crate::util::proptest::{property, Gen};
        property("waitqueue linkage", 100, |g: &mut Gen| {
            let mut q = WaitQueue::new();
            let mut live: Vec<(QueueRef, u64)> = Vec::new();
            let mut expect: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(1..200) {
                if live.is_empty() || g.bool(0.6) {
                    let r = q.push_back(task(next_id));
                    live.push((r, next_id));
                    expect.push(next_id);
                    next_id += 1;
                } else {
                    let i = g.usize_in(0..live.len());
                    let (r, id) = live.swap_remove(i);
                    let t = q.remove(r);
                    if t.id.0 != id {
                        return Err(format!("removed {} expected {}", t.id.0, id));
                    }
                    expect.retain(|&x| x != id);
                }
                let got: Vec<u64> = q.window(usize::MAX).map(|(_, t)| t.id.0).collect();
                if got != expect {
                    return Err(format!("order {got:?} != {expect:?}"));
                }
                if q.len() != expect.len() {
                    return Err(format!("len {} != {}", q.len(), expect.len()));
                }
            }
            Ok(())
        });
    }
}
