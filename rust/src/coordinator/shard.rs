//! Multi-coordinator sharding behind the effect API — K
//! [`CoordinatorCore`]s under one router.
//!
//! The paper's dispatch throughput is capped by one Falkon dispatcher
//! (§3, §5.1): every notify, pickup, and index update serializes through
//! a single service instance, and §5.1 measures the ceiling at 1322–2981
//! decisions/s. The coordinator-core refactor (PR 4) turned that
//! singleton into a value — so the scale-out step is not a rewrite but a
//! *routing problem*: run K cores side by side and fan the driver's
//! events in. [`ShardedCoordinator`] is that router. It owns K fully
//! independent dispatch state machines (each with its own wait queue,
//! scheduler, pending/location index, caches, provisioner, and metrics
//! recorder) and presents the *same* event → effect API as a single
//! core, so the engines drive it unchanged.
//!
//! ## Routing table
//!
//! Every driver event is routed to exactly one shard (or fanned to all),
//! and every returned effect is rewritten from shard-local executor ids
//! to the router's global id space before the driver sees it:
//!
//! | event | routed by | effect rewrite |
//! |---|---|---|
//! | [`on_arrival`](ShardedCoordinator::on_arrival) | dominant-file hash (splitmix64 of `files[0]` mod K) | `Notify` local→global |
//! | [`on_pickup`](ShardedCoordinator::on_pickup) | executor's owning shard | `Fetch` ids local→global **+ cross-shard rewrite** |
//! | [`on_fetch_done`](ShardedCoordinator::on_fetch_done) | task's owning shard (recorded at arrival) | as pickup; a rewritten fetch reports back as a global hit |
//! | [`on_compute_done`](ShardedCoordinator::on_compute_done) | task's owning shard | `Notify` local→global |
//! | [`on_tick`](ShardedCoordinator::on_tick) / [`kick`](ShardedCoordinator::kick) | fanned to every shard, effects concatenated in shard order | `Release` lists local→global |
//! | [`register_node`](ShardedCoordinator::register_node) | round-robin over shards | `Notify` local→global |
//! | [`on_node_registered`](ShardedCoordinator::on_node_registered) | first shard with a pending allocation | `Notify` local→global |
//!
//! Tasks are partitioned by **dominant file** — the first entry of
//! θ(κ) — so all readers of a file meet in one shard and that shard's
//! scheduler sees the full pending-reader picture for it. Executors are
//! partitioned at registration (round-robin for the initial fleet;
//! allocation-demand routing afterwards), and each shard's provisioner
//! gets a `max_nodes/K` quota so the cluster cap is conserved.
//!
//! ## The cross-shard peer-fetch protocol
//!
//! Sharding splits the location index, so a file cached on shard B is
//! invisible to shard A's `resolve_access` — A would send its executor
//! to GPFS for bytes the transient fleet already holds, exactly the
//! cross-site waste DIANA-style bulk scheduling warns about. The router
//! closes the gap at the effect boundary:
//!
//! 1. a shard resolves a fetch as a persistent-store **`Miss`**;
//! 2. the router probes the *other* shards' location indexes through
//!    the read-only `CoordinatorCore::probe_holder_count`/
//!    [`probe_holder_nth`](CoordinatorCore::probe_holder_nth) seams and
//!    **rotates** a cursor over the full foreign-holder list (ascending
//!    shard order, ascending executor-id order within a shard), so a
//!    hot file's cross-shard reads spread over all of its sources —
//!    fully deterministic, no PRNG;
//! 3. on a hit it rewrites the plan to a **remote-peer fetch**
//!    (`kind = HitGlobal`, `peer =` the foreign holder's global id) and
//!    remembers the task;
//! 4. when the driver reports the transfer done, the router overrides
//!    the observed access as a global hit, so the owning shard's
//!    recorder tallies what actually moved — and the transfer is
//!    accounted on **both** shards
//!    ([`cross_in`](crate::metrics::ShardTally::cross_in) at the
//!    destination, [`cross_out`](crate::metrics::ShardTally::cross_out)
//!    at the source).
//!
//! The foreign shard's state is never mutated: its executor serves the
//! bytes (the driver routes the transfer over that node's disk + NIC
//! links, GridFTP-style), but its cache, index, and scheduler are
//! untouched. Each core's single-mutation-site invariants survive
//! sharding intact.
//!
//! ## The K = 1 parity contract
//!
//! With one shard the router is a **bit-identical pass-through**: ids are
//! not remapped, no task→shard map is kept, the cross-shard probe never
//! runs (there is no other shard), and every event method delegates
//! straight to the single core. `rust/tests/shard_parity.rs` proves it —
//! identical effect streams, dispatch order, and access tallies against
//! a bare [`CoordinatorCore`] across all five dispatch policies — and
//! checks the K = 4 conservation laws (every task dispatched exactly
//! once, access tallies sum across shards, cross-fetch count ≤ one per
//! task). `perf_hotpath` snapshots the router's work counters as
//! `shard/*` and `tools/bench_gate.py` gates them.

use crate::coordinator::core::{CoordinatorCore, CoreConfig, Effect};
use crate::coordinator::model::apportion;
use crate::coordinator::provisioner::AllocationPolicy;
use crate::coordinator::queue::Task;
use crate::coordinator::scheduler::SchedulerStats;
use crate::coordinator::AccessKind;
use crate::ids::{ExecutorId, FileId, TaskId};
use crate::metrics::{Recorder, ShardCounters};
use crate::util::prng::Pcg64;
use crate::util::time::Micros;
use std::collections::HashMap;

/// K independent [`CoordinatorCore`]s behind the single-core event API.
/// Construct with [`ShardedCoordinator::new`]; drive exactly like a
/// core; read the cross-shard accounting from
/// [`ShardedCoordinator::counters`]. See the module docs for the
/// routing table and the cross-shard fetch protocol.
#[derive(Debug)]
pub struct ShardedCoordinator {
    cores: Vec<CoordinatorCore>,
    /// Global executor id → (shard, shard-local id). Empty at K = 1
    /// (ids pass through untouched).
    to_local: HashMap<u32, (usize, u32)>,
    /// Per-shard: shard-local id → global id. Entries are replaced when
    /// a core recycles a released local id for a new node.
    to_global: Vec<HashMap<u32, u32>>,
    next_global: u32,
    /// Task id → owning shard, recorded at arrival, dropped at
    /// completion/failure. Not maintained at K = 1.
    task_shard: HashMap<u64, usize>,
    /// Tasks whose *current* fetch was rewritten into a cross-shard
    /// peer transfer (task id → (bytes, global source id)), so the
    /// completion reports back as a global hit and the source's serving
    /// refcount drains.
    cross_inflight: HashMap<u64, (u64, ExecutorId)>,
    /// Active cross-shard transfers per *source* executor (global id).
    /// The source's own shard cannot see this serving window — the plan
    /// lives on the destination shard — so the router filters its
    /// `Release` effects with it.
    cross_serving: HashMap<u32, u32>,
    /// Rotation cursor for cross-shard source balancing: consecutive
    /// rewrites of the same hot file draw successive foreign holders.
    probe_cursor: u64,
    /// Round-robin cursor for returning recycled effect buffers, so
    /// every shard's scratch pool refills (not just shard 0's).
    next_recycle: usize,
    /// Round-robin cursor for initial-fleet registration.
    next_register: usize,
    /// True when the shards run `--allocation model`: the router then
    /// rebalances per-shard node quotas by observed arrival pressure
    /// each tick (see [`ShardedCoordinator::rebalance_quotas`]).
    model_allocation: bool,
    /// Quota-rebalance rounds that actually moved at least one shard's
    /// quota (surfaced as the `model/shard_rebalances` bench counter).
    quota_rebalances: u64,
    /// Router-level tallies (events fanned, cross-shard fetches,
    /// per-shard routing).
    counters: ShardCounters,
}

/// Trailing window (seconds) over which [`rebalance_quotas`]
/// (ShardedCoordinator::rebalance_quotas) sums per-shard arrivals —
/// matches the model controller's default signal window so quota moves
/// and target moves see the same history.
const REBALANCE_WINDOW_S: u64 = 30;

impl ShardedCoordinator {
    /// Build a `shards`-way router. Each shard gets a clone of `config`
    /// with a `max_nodes / shards` provisioner quota (remainder spread
    /// over the low shards) and its own PRNG stream forked from `rng`.
    /// With `shards == 1` the single core receives `config` and `rng`
    /// verbatim — the bit-identical pass-through the parity suite pins.
    ///
    /// Callers must keep `shards <= config.max_nodes` (validated by
    /// [`crate::config::ExperimentConfig::validate`]); a shard with a
    /// zero node quota could never provision an executor and tasks
    /// hashed to it would wait forever.
    pub fn new(config: CoreConfig, shards: usize, mut rng: Pcg64) -> Self {
        let k = shards.max(1);
        let model_allocation = config.provisioner.allocation == AllocationPolicy::Model;
        // Hard assert (not debug): a zero-quota shard can never register
        // an executor, so tasks hashed to it would stall a release-build
        // run forever instead of failing here at construction.
        assert!(
            k == 1 || config.max_nodes >= k,
            "{k} shards need {k} node quotas but max_nodes is {}",
            config.max_nodes
        );
        let cores: Vec<CoordinatorCore> = if k == 1 {
            vec![CoordinatorCore::new(config, rng)]
        } else {
            let base = config.max_nodes / k;
            let rem = config.max_nodes % k;
            (0..k)
                .map(|s| {
                    let mut shard_cfg = config.clone();
                    shard_cfg.max_nodes = base + usize::from(s < rem);
                    CoordinatorCore::new(shard_cfg, rng.fork(s as u64))
                })
                .collect()
        };
        ShardedCoordinator {
            to_local: HashMap::new(),
            to_global: vec![HashMap::new(); k],
            next_global: 0,
            task_shard: HashMap::new(),
            cross_inflight: HashMap::new(),
            cross_serving: HashMap::new(),
            probe_cursor: 0,
            next_recycle: 0,
            next_register: 0,
            model_allocation,
            quota_rebalances: 0,
            counters: ShardCounters::new(k),
            cores,
        }
    }

    /// Quota-rebalance rounds that moved at least one shard's quota.
    pub fn quota_rebalances(&self) -> u64 {
        self.quota_rebalances
    }

    /// Install cluster-calibrated model-controller parameters on every
    /// shard (no-op on shards without a controller, i.e. any allocation
    /// policy but `model`). The engines call this right after
    /// construction so the online §3 solve uses the same store/disk
    /// rates and per-task overhead the offline model was validated
    /// with.
    pub fn set_model_config(&mut self, cfg: crate::coordinator::model::ModelControllerConfig) {
        for core in &mut self.cores {
            core.set_model_config(cfg);
        }
    }

    /// Sum of every shard's model-controller decision counters; `None`
    /// when no shard runs the model policy.
    pub fn merged_model_stats(&self) -> Option<crate::coordinator::model::ModelStats> {
        let mut out: Option<crate::coordinator::model::ModelStats> = None;
        for core in &self.cores {
            if let Some(s) = core.model_stats() {
                let acc = out.get_or_insert_with(Default::default);
                acc.solves += s.solves;
                acc.target_changes += s.target_changes;
                acc.deadband_holds += s.deadband_holds;
            }
        }
        out
    }

    /// Number of shards (coordinator cores).
    pub fn shards(&self) -> usize {
        self.cores.len()
    }

    /// Router-level tallies so far.
    pub fn counters(&self) -> &ShardCounters {
        &self.counters
    }

    /// Return an enacted effect buffer to a shard's scratch pool (see
    /// [`CoordinatorCore::recycle_effects`]). Buffers round-robin over
    /// the shards so every pool refills; skipping this is always
    /// correct, just slower. Deterministic (cursor, no PRNG).
    pub fn recycle_effects(&mut self, effects: Vec<Effect>) {
        let k = self.cores.len();
        self.cores[self.next_recycle % k].recycle_effects(effects);
        self.next_recycle = (self.next_recycle + 1) % k;
    }

    /// Fresh scratch-buffer allocations across all shards (pool misses
    /// on the event path) — the `scale/allocs_per_event` numerator.
    pub fn alloc_events(&self) -> u64 {
        self.cores.iter().map(|c| c.alloc_events()).sum()
    }

    /// Events that took an effect buffer, across all shards — the
    /// `scale/allocs_per_event` denominator.
    pub fn effect_events(&self) -> u64 {
        self.cores.iter().map(|c| c.effect_events()).sum()
    }

    /// Stale reports rejected by the cores (tasks not in flight) plus
    /// those the router bounced before reaching a core.
    pub fn stale_events(&self) -> u64 {
        self.counters.stale_events + self.cores.iter().map(|c| c.stale_events()).sum::<u64>()
    }

    /// Bytes behind every shard's dense dispatch tables.
    pub fn table_bytes(&self) -> u64 {
        self.cores.iter().map(|c| c.table_bytes()).sum()
    }

    /// Read access to one shard's core (tests, benches).
    pub fn core(&self, shard: usize) -> &CoordinatorCore {
        &self.cores[shard]
    }

    /// The shard a task with dominant file `file` routes to: a
    /// splitmix64 finalizer over the file id, mod K. Stateless and
    /// deterministic; exposed so tests can construct workloads with a
    /// known cross-shard shape.
    pub fn shard_of_file(&self, file: FileId) -> usize {
        let k = self.cores.len();
        if k == 1 {
            return 0;
        }
        let mut x = (file.0 as u64).wrapping_add(0x9e37_79b9_7f4a_7c15);
        x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        x ^= x >> 31;
        (x % k as u64) as usize
    }

    // ---- id translation -------------------------------------------------

    fn g2l(&self, global: ExecutorId) -> Option<(usize, ExecutorId)> {
        if self.cores.len() == 1 {
            return Some((0, global));
        }
        self.to_local
            .get(&global.0)
            .map(|&(shard, local)| (shard, ExecutorId(local)))
    }

    fn l2g(&self, shard: usize, local: ExecutorId) -> ExecutorId {
        if self.cores.len() == 1 {
            return local;
        }
        ExecutorId(self.to_global[shard][&local.0])
    }

    /// Bind a freshly registered shard-local executor to a new global id.
    fn bind(&mut self, shard: usize, local: ExecutorId) -> ExecutorId {
        if self.cores.len() == 1 {
            return local;
        }
        let global = self.next_global;
        self.next_global += 1;
        self.to_local.insert(global, (shard, local.0));
        self.to_global[shard].insert(local.0, global);
        ExecutorId(global)
    }

    /// The shard that owns `exec`, if it is registered.
    pub fn shard_of_exec(&self, exec: ExecutorId) -> Option<usize> {
        self.g2l(exec).map(|(shard, _)| shard)
    }

    /// The shard that owns `task_id`, or `None` when the router never
    /// saw it arrive (a stale or byzantine event). At K = 1 the single
    /// core is always the owner — its own in-flight table makes the
    /// staleness call instead.
    fn shard_of_task(&self, task_id: TaskId) -> Option<usize> {
        if self.cores.len() == 1 {
            Some(0)
        } else {
            self.task_shard.get(&task_id.0).copied()
        }
    }

    // ---- effect rewriting -----------------------------------------------

    /// Rewrite one shard's effects into the global id space **in
    /// place**, applying the cross-shard fetch rewrite to GPFS misses.
    /// Identity at K = 1. The buffer the core handed over is mutated and
    /// passed through — the router allocates nothing per event.
    fn rewrite(&mut self, shard: usize, mut effects: Vec<Effect>) -> Vec<Effect> {
        if self.cores.len() == 1 {
            return effects;
        }
        for e in &mut effects {
            self.rewrite_one(shard, e);
        }
        effects
    }

    fn rewrite_one(&mut self, shard: usize, effect: &mut Effect) {
        match effect {
            Effect::Notify(e) => *e = self.l2g(shard, *e),
            Effect::Fetch(plan) => {
                plan.exec = self.l2g(shard, plan.exec);
                plan.peer = plan.peer.map(|p| self.l2g(shard, p));
                if plan.kind == AccessKind::Miss {
                    if let Some((src, holder)) = self.probe_foreign(shard, plan.file) {
                        let peer = self.l2g(src, holder);
                        plan.kind = AccessKind::HitGlobal;
                        plan.peer = Some(peer);
                        self.cross_inflight
                            .insert(plan.task_id.0, (plan.bytes, peer));
                        *self.cross_serving.entry(peer.0).or_insert(0) += 1;
                        self.counters.cross_fetches += 1;
                        self.counters.cross_bytes += plan.bytes;
                        self.counters.per_shard[shard].cross_in += 1;
                        self.counters.per_shard[src].cross_out += 1;
                    }
                }
            }
            Effect::Compute { exec, .. } => *exec = self.l2g(shard, *exec),
            Effect::Allocate(_) => {}
            Effect::Release(execs) => {
                // The owning core already withheld executors serving
                // *its own* peer transfers; the router additionally
                // withholds sources of cross-shard transfers, which the
                // owning shard cannot see. Withheld executors stay
                // idle-listed and are retried next tick. Same list
                // order as before, filtered in place.
                for e in execs.iter_mut() {
                    *e = self.l2g(shard, *e);
                }
                let cross_serving = &self.cross_serving;
                let counters = &mut self.counters;
                execs.retain(|g| {
                    if cross_serving.contains_key(&g.0) {
                        counters.cross_release_deferrals += 1;
                        false
                    } else {
                        true
                    }
                });
            }
        }
    }

    /// Foreign-holder probe with **source balancing**: the candidate
    /// list is every foreign holder of `file` (concatenated in
    /// ascending shard order, ascending executor-id order within a
    /// shard), and a rotating cursor picks among them, so consecutive
    /// cross-shard fetches of a hot file spread load over all of its
    /// sources instead of always drafting the first. Deterministic (no
    /// PRNG) and read-only on every core; the cursor advances only when
    /// a source is drafted.
    fn probe_foreign(&mut self, owner: usize, file: FileId) -> Option<(usize, ExecutorId)> {
        if !self.cores[owner].caching_enabled() {
            // first-available never caches anywhere: nothing to find.
            return None;
        }
        let k = self.cores.len();
        let mut counts = vec![0usize; k];
        let mut total = 0usize;
        for (s, count) in counts.iter_mut().enumerate() {
            if s != owner {
                *count = self.cores[s].probe_holder_count(file);
                total += *count;
            }
        }
        if total == 0 {
            return None;
        }
        let mut pick = (self.probe_cursor % total as u64) as usize;
        self.probe_cursor = self.probe_cursor.wrapping_add(1);
        for (s, &count) in counts.iter().enumerate() {
            if pick < count {
                let holder = self.cores[s]
                    .probe_holder_nth(file, pick)
                    .expect("holder counted above");
                return Some((s, holder));
            }
            pick -= count;
        }
        unreachable!("cursor reduced below total")
    }

    /// Drain one task's cross-shard bookkeeping: drops the in-flight
    /// entry and one serving reference on its source. Tolerates a
    /// source whose refcounts were already dropped wholesale by
    /// [`ShardedCoordinator::on_executor_failed`].
    fn cross_done(&mut self, task_id: TaskId) -> Option<u64> {
        let (bytes, peer) = self.cross_inflight.remove(&task_id.0)?;
        if let Some(n) = self.cross_serving.get_mut(&peer.0) {
            *n -= 1;
            if *n == 0 {
                self.cross_serving.remove(&peer.0);
            }
        }
        Some(bytes)
    }

    // ---- node lifecycle -------------------------------------------------

    /// Register a node of the initial fleet (or a driver enacting
    /// [`Effect::Allocate`] without LRM bookkeeping): shards take turns
    /// in round-robin order so the fleet starts balanced.
    pub fn register_node(&mut self, now: Micros) -> (ExecutorId, Vec<Effect>) {
        self.counters.router_events += 1;
        let shard = self.next_register % self.cores.len();
        self.next_register += 1;
        let (local, effects) = self.cores[shard].register_node(now);
        let global = self.bind(shard, local);
        let effects = self.rewrite(shard, effects);
        (global, effects)
    }

    /// A node requested through [`Effect::Allocate`] finished its LRM
    /// bootstrap. Routed to the first shard with a pending allocation —
    /// allocations and registrations pair up by count, not provenance,
    /// so every shard's pending total drains exactly once per
    /// registration. Falls back to plain registration on the emptiest
    /// shard if no shard is waiting (defensive; unreachable under the
    /// engines' allocate-then-register discipline).
    pub fn on_node_registered(&mut self, now: Micros) -> (ExecutorId, Vec<Effect>) {
        self.counters.router_events += 1;
        let k = self.cores.len();
        let waiting = (0..k).find(|&s| self.cores[s].pending_allocations() > 0);
        let (shard, (local, effects)) = match waiting {
            Some(s) => (s, self.cores[s].on_node_registered(now)),
            None => {
                let s = (0..k)
                    .min_by_key(|&s| self.cores[s].node_count())
                    .expect("at least one shard");
                (s, self.cores[s].register_node(now))
            }
        };
        let global = self.bind(shard, local);
        let effects = self.rewrite(shard, effects);
        (global, effects)
    }

    /// Release an idle executor named in [`Effect::Release`]: scrubs it
    /// from its shard and drops the id binding. Unknown ids are ignored
    /// (the executor was already released).
    pub fn release_node(&mut self, exec: ExecutorId) {
        self.counters.router_events += 1;
        let Some((shard, local)) = self.g2l(exec) else {
            return;
        };
        self.cores[shard].release_node(local);
        if self.cores.len() > 1 {
            self.to_local.remove(&exec.0);
            self.to_global[shard].remove(&local.0);
        }
    }

    // ---- dispatch events ------------------------------------------------

    /// A task arrived: routed to its dominant file's shard (see
    /// [`ShardedCoordinator::shard_of_file`]).
    pub fn on_arrival(
        &mut self,
        task: Task,
        interval: u32,
        rate: f64,
        now: Micros,
    ) -> Vec<Effect> {
        self.counters.router_events += 1;
        let shard = task.files.first().map_or(0, |&f| self.shard_of_file(f));
        self.counters.per_shard[shard].tasks_routed += 1;
        if self.cores.len() > 1 {
            self.task_shard.insert(task.id.0, shard);
        }
        let effects = self.cores[shard].on_arrival(task, interval, rate, now);
        self.rewrite(shard, effects)
    }

    /// An executor asks for work: routed to its owning shard. Returns
    /// nothing if the executor was released meanwhile (mirrors the
    /// core's own guard).
    pub fn on_pickup(&mut self, exec: ExecutorId, now: Micros) -> Vec<Effect> {
        self.counters.router_events += 1;
        let Some((shard, local)) = self.g2l(exec) else {
            return Vec::new();
        };
        let effects = self.cores[shard].on_pickup(local, now);
        self.rewrite(shard, effects)
    }

    /// The driver finished one file transfer. If the router rewrote this
    /// fetch into a cross-shard peer transfer, the owning shard records
    /// it as the global hit it actually was (an explicit `observed`
    /// report from a live driver takes precedence).
    pub fn on_fetch_done(
        &mut self,
        task_id: TaskId,
        now: Micros,
        observed: Option<(AccessKind, u64)>,
    ) -> Vec<Effect> {
        self.counters.router_events += 1;
        let Some(shard) = self.shard_of_task(task_id) else {
            self.counters.stale_events += 1;
            return Vec::new();
        };
        let observed = match (self.cross_done(task_id), observed) {
            (Some(bytes), None) => Some((AccessKind::HitGlobal, bytes)),
            (_, explicit) => explicit,
        };
        let effects = self.cores[shard].on_fetch_done(task_id, now, observed);
        self.rewrite(shard, effects)
    }

    /// A task's compute finished on its executor.
    pub fn on_compute_done(
        &mut self,
        task_id: TaskId,
        now: Micros,
        completed_at: Micros,
    ) -> Vec<Effect> {
        self.counters.router_events += 1;
        let Some(shard) = self.shard_of_task(task_id) else {
            self.counters.stale_events += 1;
            return Vec::new();
        };
        self.task_shard.remove(&task_id.0);
        let effects = self.cores[shard].on_compute_done(task_id, now, completed_at);
        self.rewrite(shard, effects)
    }

    /// A dispatched task failed on its executor (live-driver semantics;
    /// resubmission goes back through [`ShardedCoordinator::on_arrival`]
    /// and is re-routed by dominant file as usual).
    pub fn on_task_failed(&mut self, task_id: TaskId, now: Micros) -> Vec<Effect> {
        self.counters.router_events += 1;
        let Some(shard) = self.shard_of_task(task_id) else {
            self.counters.stale_events += 1;
            return Vec::new();
        };
        self.task_shard.remove(&task_id.0);
        self.cross_done(task_id);
        let effects = self.cores[shard].on_task_failed(task_id, now);
        self.rewrite(shard, effects)
    }

    /// An executor crashed. Routed to its owning shard's
    /// [`CoordinatorCore::on_executor_failed`] (scrub + §4.2 requeue);
    /// the router additionally drops the dead node's id bindings, the
    /// cross-shard bookkeeping of every re-queued task, and — since a
    /// dead source can no longer serve — its whole serving refcount
    /// (destination drivers fall back to persistent storage and report
    /// the observed access). Unknown ids are no-ops.
    pub fn on_executor_failed(&mut self, exec: ExecutorId, now: Micros) -> Vec<Effect> {
        self.counters.router_events += 1;
        let Some((shard, local)) = self.g2l(exec) else {
            return Vec::new();
        };
        self.counters.exec_failures += 1;
        self.cross_serving.remove(&exec.0);
        let (requeued, effects) = self.cores[shard].on_executor_failed(local, now);
        for t in &requeued {
            // Requeued tasks stay routed to the same shard (their
            // task_shard entry survives); only the dead fetch's
            // cross-shard leg is scrubbed.
            self.cross_done(*t);
        }
        if self.cores.len() > 1 {
            self.to_local.remove(&exec.0);
            self.to_global[shard].remove(&local.0);
        }
        self.rewrite(shard, effects)
    }

    /// Periodic sample + provisioning decision, fanned to every shard;
    /// effects are concatenated in shard order (deterministic). Under
    /// `--allocation model` at K > 1 the router first rebalances the
    /// shards' node quotas by observed arrival pressure, so each
    /// shard's controller solves against a share of the cluster cap
    /// proportional to its recent load.
    pub fn on_tick(&mut self, now: Micros) -> Vec<Effect> {
        self.counters.router_events += 1;
        if self.model_allocation && self.cores.len() > 1 {
            self.rebalance_quotas(now);
        }
        if self.cores.len() == 1 {
            return self.cores[0].on_tick(now);
        }
        let mut out = Vec::new();
        for shard in 0..self.cores.len() {
            let effects = self.cores[shard].on_tick(now);
            let mut effects = self.rewrite(shard, effects);
            out.extend(effects.drain(..));
            self.cores[shard].recycle_effects(effects);
        }
        out
    }

    /// Re-apportion the cluster's node cap over the shards by recent
    /// arrival pressure: each shard's weight is its queued backlog plus
    /// the arrivals its recorder saw in the trailing
    /// [`REBALANCE_WINDOW_S`] seconds, and
    /// [`apportion`](crate::coordinator::model::apportion) splits the
    /// conserved total (largest-remainder, floor 1 — no shard is ever
    /// starved to a zero quota). Deterministic: weights are read in
    /// shard order from state the driver already advanced. K = 1 never
    /// calls this, preserving the pass-through contract.
    fn rebalance_quotas(&mut self, now: Micros) {
        let total: usize = self.cores.iter().map(|c| c.node_quota()).sum();
        if total < self.cores.len() {
            return;
        }
        let sec = now.as_secs();
        let from = sec.saturating_sub(REBALANCE_WINDOW_S) as usize;
        let weights: Vec<f64> = self
            .cores
            .iter()
            .map(|c| {
                let buckets = c.rec.ts.buckets();
                let to = buckets.len().min(sec as usize + 1);
                let arrivals: u64 = buckets[from.min(to)..to]
                    .iter()
                    .map(|b| u64::from(b.arrivals))
                    .sum();
                (arrivals + c.queue_len() as u64) as f64
            })
            .collect();
        let quotas = apportion(total, &weights, 1);
        let mut moved = false;
        for (core, &quota) in self.cores.iter_mut().zip(&quotas) {
            if core.node_quota() != quota {
                core.set_node_quota(quota);
                moved = true;
            }
        }
        if moved {
            self.quota_rebalances += 1;
        }
    }

    /// Progress safety net, fanned to every shard (a shard with waiting
    /// tasks and free executors kicks independently of the others).
    pub fn kick(&mut self) -> Vec<Effect> {
        self.counters.router_events += 1;
        if self.cores.len() == 1 {
            return self.cores[0].kick();
        }
        let mut out = Vec::new();
        for shard in 0..self.cores.len() {
            let effects = self.cores[shard].kick();
            let mut effects = self.rewrite(shard, effects);
            out.extend(effects.drain(..));
            self.cores[shard].recycle_effects(effects);
        }
        out
    }

    // ---- read-only aggregates -------------------------------------------

    /// Total queued tasks across shards.
    pub fn queue_len(&self) -> usize {
        self.cores.iter().map(|c| c.queue_len()).sum()
    }

    /// True when no shard has waiting tasks.
    pub fn queue_is_empty(&self) -> bool {
        self.cores.iter().all(|c| c.queue_is_empty())
    }

    /// Executors with a free slot, across shards.
    pub fn free_count(&self) -> usize {
        self.cores.iter().map(|c| c.free_count()).sum()
    }

    /// Registered executors across shards.
    pub fn node_count(&self) -> usize {
        self.cores.iter().map(|c| c.node_count()).sum()
    }

    /// Release decisions withheld across all shards (core-level
    /// peer-serving deferrals; the router's own cross-shard deferrals
    /// are in [`ShardCounters::cross_release_deferrals`]).
    pub fn release_deferrals(&self) -> u64 {
        self.cores.iter().map(|c| c.release_deferrals()).sum()
    }

    /// Cross-check every shard's coordinator state plus the router's
    /// own bookkeeping — the chaos oracle's replica-accounting
    /// invariant. Read-only; `Err` names the offending shard.
    #[doc(hidden)]
    pub fn check_integrity(&self) -> Result<(), String> {
        for (s, core) in self.cores.iter().enumerate() {
            core.check_integrity().map_err(|e| format!("shard {s}: {e}"))?;
        }
        let mut serving: HashMap<u32, u32> = HashMap::new();
        for &(_, peer) in self.cross_inflight.values() {
            *serving.entry(peer.0).or_insert(0) += 1;
        }
        // A failed source's refcounts are dropped wholesale while its
        // destinations' fetches drain, so live entries may undercount
        // the in-flight plans — but never the reverse, and never for a
        // registered source.
        for (&e, &n) in &self.cross_serving {
            let actual = serving.get(&e).copied().unwrap_or(0);
            if n > actual {
                return Err(format!(
                    "cross-serving refcount {n} on e{e} exceeds {actual} in-flight plan(s)"
                ));
            }
        }
        Ok(())
    }

    // ---- end-of-run reporting -------------------------------------------

    /// Take every shard's dispatch trace, concatenated in shard order,
    /// and fill the per-shard dispatch tallies. At K = 1 this is exactly
    /// the core's trace. Call before
    /// [`ShardedCoordinator::take_counters`].
    pub fn take_dispatch_log(&mut self) -> Vec<TaskId> {
        let mut out = Vec::new();
        for (shard, core) in self.cores.iter_mut().enumerate() {
            let log = core.take_dispatch_log();
            self.counters.per_shard[shard].dispatches += log.len() as u64;
            out.extend(log);
        }
        out
    }

    /// Sum of every shard's scheduler counters.
    pub fn merged_sched_stats(&self) -> SchedulerStats {
        let mut out = SchedulerStats::default();
        for core in &self.cores {
            let s = core.sched_stats();
            out.notify_decisions += s.notify_decisions;
            out.pickups += s.pickups;
            out.tasks_dispatched += s.tasks_dispatched;
            out.tasks_inspected += s.tasks_inspected;
            out.full_hit_dispatches += s.full_hit_dispatches;
            out.holder_recounts += s.holder_recounts;
        }
        out
    }

    /// Take the shards' recorders merged into one cluster view
    /// ([`Recorder::absorb`]). At K = 1 the single recorder is moved out
    /// untouched, so single-shard reporting is bit-identical to a bare
    /// core's.
    pub fn take_merged_recorder(&mut self) -> Recorder {
        if self.cores.len() == 1 {
            return std::mem::take(&mut self.cores[0].rec);
        }
        let mut merged = Recorder::new();
        for core in &mut self.cores {
            merged.absorb(std::mem::take(&mut core.rec));
        }
        merged
    }

    /// Take every shard's recorder *unmerged*, in shard order — the
    /// emit-shards seam (`figures --emit-shards`, docs/LIVE.md). Each
    /// entry is exactly what [`ShardedCoordinator::take_merged_recorder`]
    /// would have absorbed, so absorbing the returned recorders into a
    /// fresh one in order reproduces the merged view bit-for-bit
    /// (`Recorder::absorb` is lossless and absorb-into-fresh is exact).
    pub fn take_shard_recorders(&mut self) -> Vec<Recorder> {
        self.cores
            .iter_mut()
            .map(|core| std::mem::take(&mut core.rec))
            .collect()
    }

    /// Take the router tallies (call after
    /// [`ShardedCoordinator::take_dispatch_log`], which fills the
    /// per-shard dispatch counts).
    pub fn take_counters(&mut self) -> ShardCounters {
        std::mem::take(&mut self.counters)
    }

    /// Test/bench support: the minimal synchronous driver. Enacts
    /// `effects` depth-first at one instant — notifications become
    /// immediate pickups, fetches and computes complete instantly,
    /// allocations register instantly, releases are unconditional — so
    /// fixtures can run a workload to quiescence without an event loop.
    /// Real drivers (the engines) model time and data movement instead;
    /// this exists so the crate's three fixture sites share one
    /// enactment loop that a new [`Effect`] variant cannot silently
    /// miss.
    #[doc(hidden)]
    pub fn drain_effects(&mut self, effects: Vec<Effect>, now: Micros) {
        let mut stack = effects;
        while let Some(effect) = stack.pop() {
            match effect {
                Effect::Notify(e) => {
                    let mut effs = self.on_pickup(e, now);
                    stack.extend(effs.drain(..));
                    self.recycle_effects(effs);
                }
                Effect::Fetch(plan) => {
                    let mut effs = self.on_fetch_done(plan.task_id, now, None);
                    stack.extend(effs.drain(..));
                    self.recycle_effects(effs);
                }
                Effect::Compute { task_id, .. } => {
                    let mut effs = self.on_compute_done(task_id, now, now);
                    stack.extend(effs.drain(..));
                    self.recycle_effects(effs);
                }
                Effect::Allocate(n) => {
                    for _ in 0..n {
                        let (_, mut effs) = self.on_node_registered(now);
                        stack.extend(effs.drain(..));
                        self.recycle_effects(effs);
                    }
                }
                Effect::Release(execs) => {
                    for e in execs {
                        self.release_node(e);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{CacheConfig, EvictionPolicy};
    use crate::coordinator::core::FileSizes;
    use crate::coordinator::scheduler::{DispatchPolicy, SchedulerConfig};

    fn config(policy: DispatchPolicy) -> CoreConfig {
        CoreConfig {
            scheduler: SchedulerConfig {
                policy,
                ..SchedulerConfig::default()
            },
            provisioner: crate::coordinator::provisioner::ProvisionerConfig::default(),
            cache: CacheConfig {
                capacity_bytes: 1_000,
                policy: EvictionPolicy::Lru,
            },
            max_nodes: 8,
            slots_per_node: 1,
            file_sizes: FileSizes::Uniform(10),
        }
    }

    fn router(policy: DispatchPolicy, shards: usize) -> ShardedCoordinator {
        ShardedCoordinator::new(config(policy), shards, Pcg64::seeded(3))
    }

    fn task(id: u64, files: &[u32]) -> Task {
        Task {
            id: TaskId(id),
            files: files.iter().map(|&f| FileId(f)).collect(),
            compute: Micros::from_millis(1),
            arrival: Micros::ZERO,
        }
    }

    /// Two files guaranteed to live on different shards of `r`.
    fn files_on_distinct_shards(r: &ShardedCoordinator) -> (u32, u32) {
        let a = 0u32;
        let sa = r.shard_of_file(FileId(a));
        let b = (1..1_000u32)
            .find(|&f| r.shard_of_file(FileId(f)) != sa)
            .expect("hash spreads over shards");
        (a, b)
    }

    #[test]
    fn single_shard_is_a_pass_through() {
        let mut r = router(DispatchPolicy::GoodCacheCompute, 1);
        let mut c = CoordinatorCore::new(config(DispatchPolicy::GoodCacheCompute), Pcg64::seeded(3));
        let (re, reffs) = r.register_node(Micros::ZERO);
        let (ce, ceffs) = c.register_node(Micros::ZERO);
        assert_eq!(re, ce);
        assert_eq!(format!("{reffs:?}"), format!("{ceffs:?}"));
        let r_effs = r.on_arrival(task(0, &[7]), 0, 0.0, Micros::ZERO);
        let c_effs = c.on_arrival(task(0, &[7]), 0, 0.0, Micros::ZERO);
        assert_eq!(format!("{r_effs:?}"), format!("{c_effs:?}"));
        let r_effs = r.on_pickup(re, Micros::ZERO);
        let c_effs = c.on_pickup(ce, Micros::ZERO);
        assert_eq!(format!("{r_effs:?}"), format!("{c_effs:?}"));
        assert_eq!(r.counters().cross_fetches, 0);
        assert_eq!(r.shards(), 1);
    }

    #[test]
    fn tasks_route_by_dominant_file() {
        let mut r = router(DispatchPolicy::GoodCacheCompute, 4);
        // Register two nodes per shard.
        for _ in 0..8 {
            let (e, effs) = r.register_node(Micros::ZERO);
            assert!(r.shard_of_exec(e).is_some());
            r.drain_effects(effs, Micros::ZERO); // cancels the fresh reservation
        }
        let (a, b) = files_on_distinct_shards(&r);
        let sa = r.shard_of_file(FileId(a));
        let sb = r.shard_of_file(FileId(b));
        let effs = r.on_arrival(task(0, &[a]), 0, 0.0, Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
        let effs = r.on_arrival(task(1, &[b]), 0, 0.0, Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
        assert_eq!(r.counters().per_shard[sa].tasks_routed, 1);
        assert_eq!(r.counters().per_shard[sb].tasks_routed, 1);
        // Same-shard data never crosses shards.
        assert_eq!(r.counters().cross_fetches, 0);
        assert_eq!(r.core(sa).rec.access_counts().2, 1, "miss in shard A");
        assert_eq!(r.core(sb).rec.access_counts().2, 1, "miss in shard B");
    }

    #[test]
    fn gpfs_miss_with_foreign_holder_becomes_cross_shard_peer_fetch() {
        let mut r = router(DispatchPolicy::GoodCacheCompute, 2);
        for _ in 0..4 {
            let (_, effs) = r.register_node(Micros::ZERO);
            r.drain_effects(effs, Micros::ZERO);
        }
        let (a, b) = files_on_distinct_shards(&r);
        let sb = r.shard_of_file(FileId(b));
        // Seed file b into its home shard's cache.
        let effs = r.on_arrival(task(0, &[b]), 0, 0.0, Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
        assert!(r.core(sb).probe_holder(FileId(b)).is_some());

        // A task dominant in the *other* shard also reads b: its home
        // shard misses, the router must rewrite to a remote-peer plan.
        let sa = r.shard_of_file(FileId(a));
        assert_ne!(sa, sb);
        let effs = r.on_arrival(task(1, &[a, b]), 0, 0.0, Micros::ZERO);
        // Walk the effects by hand to inspect the plans.
        let mut stack = effs;
        let mut saw_cross = false;
        while let Some(effect) = stack.pop() {
            match effect {
                Effect::Notify(e) => stack.extend(r.on_pickup(e, Micros::ZERO)),
                Effect::Fetch(p) => {
                    if p.file == FileId(b) && p.task_id == TaskId(1) {
                        assert_eq!(p.kind, AccessKind::HitGlobal, "rewritten to peer");
                        let peer = p.peer.expect("cross-shard plan names its source");
                        assert_eq!(r.shard_of_exec(peer), Some(sb), "source is foreign");
                        saw_cross = true;
                    }
                    stack.extend(r.on_fetch_done(p.task_id, Micros::ZERO, None));
                }
                Effect::Compute { task_id, .. } => {
                    stack.extend(r.on_compute_done(task_id, Micros::ZERO, Micros::ZERO));
                }
                other => panic!("unexpected effect {other:?}"),
            }
        }
        assert!(saw_cross, "the b-fetch never crossed shards");
        let c = r.counters();
        assert_eq!(c.cross_fetches, 1);
        assert_eq!(c.cross_bytes, 10);
        assert_eq!(c.per_shard[sa].cross_in, 1);
        assert_eq!(c.per_shard[sb].cross_out, 1);
        assert!(c.cross_fetches_per_task() <= 1.0);
        // The transfer is recorded as a *global hit* on the owning shard.
        assert_eq!(r.core(sa).rec.access_counts().1, 1);
        // The foreign shard's recorder saw nothing (read-only seam).
        assert_eq!(r.core(sb).rec.access_counts(), (0, 0, 1));
    }

    #[test]
    fn merged_reporting_conserves_totals() {
        let mut r = router(DispatchPolicy::GoodCacheCompute, 4);
        for _ in 0..8 {
            let (_, effs) = r.register_node(Micros::ZERO);
            r.drain_effects(effs, Micros::ZERO);
        }
        let n = 40u64;
        for i in 0..n {
            let effs = r.on_arrival(task(i, &[(i % 16) as u32]), 0, 0.0, Micros::ZERO);
            r.drain_effects(effs, Micros::ZERO);
        }
        // Drain any stragglers a declined notify left queued.
        let mut guard = 0;
        while !r.queue_is_empty() {
            guard += 1;
            assert!(guard < 1_000, "router stalled draining the queue");
            let effs = r.kick();
            r.drain_effects(effs, Micros::ZERO);
        }
        let log = r.take_dispatch_log();
        assert_eq!(log.len() as u64, n);
        let mut ids: Vec<u64> = log.iter().map(|t| t.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len() as u64, n, "every task dispatched exactly once");
        let rec = r.take_merged_recorder();
        let (hl, hg, m) = rec.access_counts();
        assert_eq!(hl + hg + m, n, "one access per single-file task");
        assert_eq!(rec.tasks_done(), n);
        let counters = r.take_counters();
        assert_eq!(counters.tasks_routed(), n);
        assert_eq!(
            counters.per_shard.iter().map(|t| t.dispatches).sum::<u64>(),
            n
        );
        assert!(counters.router_events > 0);
    }

    #[test]
    fn first_available_never_probes_foreign_shards() {
        let mut r = router(DispatchPolicy::FirstAvailable, 2);
        for _ in 0..2 {
            let (_, effs) = r.register_node(Micros::ZERO);
            r.drain_effects(effs, Micros::ZERO);
        }
        for i in 0..6u64 {
            let effs = r.on_arrival(task(i, &[(i % 3) as u32]), 0, 0.0, Micros::ZERO);
            r.drain_effects(effs, Micros::ZERO);
        }
        assert_eq!(r.counters().cross_fetches, 0, "fa caches nothing anywhere");
        let rec = r.take_merged_recorder();
        assert_eq!(rec.access_counts(), (0, 0, 6));
    }

    /// Drive `task(id, [a, b])` (dominant `a`, foreign-held `b`) to the
    /// point where its cross-shard fetch of `b` is in flight; returns
    /// the (destination, source) global executor ids.
    fn start_cross_fetch(
        r: &mut ShardedCoordinator,
        id: u64,
        a: u32,
        b: u32,
    ) -> (ExecutorId, ExecutorId) {
        let effs = r.on_arrival(task(id, &[a, b]), 0, 0.0, Micros::ZERO);
        let exec = match effs.as_slice() {
            [Effect::Notify(e)] => *e,
            other => panic!("expected a notify, got {other:?}"),
        };
        let effs = r.on_pickup(exec, Micros::ZERO);
        match effs.as_slice() {
            [Effect::Fetch(p)] if p.file == FileId(a) => {}
            other => panic!("expected the dominant-file fetch, got {other:?}"),
        }
        let effs = r.on_fetch_done(TaskId(id), Micros::ZERO, None);
        match effs.as_slice() {
            [Effect::Fetch(p)] => {
                assert_eq!(p.file, FileId(b));
                assert_eq!(p.kind, AccessKind::HitGlobal, "rewritten to peer");
                (p.exec, p.peer.expect("cross-shard plan names its source"))
            }
            other => panic!("expected the cross fetch, got {other:?}"),
        }
    }

    #[test]
    fn cross_shard_sources_rotate_over_foreign_holders() {
        let mut r = router(DispatchPolicy::FirstCacheAvailable, 3);
        for _ in 0..6 {
            let (_, effs) = r.register_node(Micros::ZERO);
            r.drain_effects(effs, Micros::ZERO);
        }
        let a = 0u32;
        let sa = r.shard_of_file(FileId(a));
        let b = (1..1_000u32)
            .find(|&f| r.shard_of_file(FileId(f)) != sa)
            .expect("hash spreads over shards");
        let sb = r.shard_of_file(FileId(b));
        let c = (1..1_000u32)
            .find(|&f| {
                let s = r.shard_of_file(FileId(f));
                s != sa && s != sb
            })
            .expect("all three shards are reachable");
        let sc = r.shard_of_file(FileId(c));
        // Seed b into shard B, then replicate it into shard C via a
        // cross-shard read (a [c, b] task homes in C and admits b
        // there), so b has foreign holders on two shards.
        let effs = r.on_arrival(task(0, &[b]), 0, 0.0, Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
        let effs = r.on_arrival(task(1, &[c, b]), 0, 0.0, Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
        assert!(r.core(sb).probe_holder(FileId(b)).is_some());
        assert!(r.core(sc).probe_holder(FileId(b)).is_some());
        // Two readers homed in shard A fetch b concurrently: the
        // rotating cursor must draft *different* sources for them.
        let a2 = (1..1_000u32)
            .find(|&f| r.shard_of_file(FileId(f)) == sa)
            .expect("a second shard-A file exists");
        let (_, p1) = start_cross_fetch(&mut r, 2, a, b);
        let (_, p2) = start_cross_fetch(&mut r, 3, a2, b);
        let s1 = r.shard_of_exec(p1).expect("source is registered");
        let s2 = r.shard_of_exec(p2).expect("source is registered");
        assert_ne!(s1, s2, "consecutive cross fetches must rotate sources");
        assert!([sb, sc].contains(&s1) && [sb, sc].contains(&s2));
        // Both foreign shards show up in the shard/cross_* counters.
        assert!(r.counters().per_shard[sb].cross_out >= 1);
        assert!(r.counters().per_shard[sc].cross_out >= 1);
        assert_eq!(r.counters().cross_fetches, 3);
        r.check_integrity().unwrap();
    }

    #[test]
    fn cross_shard_source_release_is_deferred_while_serving() {
        let mut cfg = config(DispatchPolicy::FirstCacheAvailable);
        cfg.provisioner.idle_release_s = 0.5;
        let mut r = ShardedCoordinator::new(cfg, 2, Pcg64::seeded(3));
        for _ in 0..4 {
            let (_, effs) = r.register_node(Micros::ZERO);
            r.drain_effects(effs, Micros::ZERO);
        }
        let (a, b) = files_on_distinct_shards(&r);
        let sb = r.shard_of_file(FileId(b));
        let effs = r.on_arrival(task(0, &[b]), 0, 0.0, Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
        let (_, src) = start_cross_fetch(&mut r, 1, a, b);
        assert_eq!(r.shard_of_exec(src), Some(sb));
        // The source's own shard lists it idle, but the router must
        // withhold its release while the cross-shard transfer is in
        // flight — the owning shard cannot see that serving window.
        let effs = r.on_tick(Micros::from_secs(10));
        assert!(
            !effs
                .iter()
                .any(|e| matches!(e, Effect::Release(v) if v.contains(&src))),
            "serving source must not be released: {effs:?}"
        );
        assert!(r.counters().cross_release_deferrals >= 1);
        r.check_integrity().unwrap();
        // Transfer drains → the next tick releases the idle source.
        let effs = r.on_fetch_done(TaskId(1), Micros::from_secs(10), None);
        assert!(matches!(effs.as_slice(), [Effect::Compute { .. }]));
        let _ = r.on_compute_done(TaskId(1), Micros::from_secs(10), Micros::from_secs(10));
        let effs = r.on_tick(Micros::from_secs(20));
        assert!(
            effs.iter()
                .any(|e| matches!(e, Effect::Release(v) if v.contains(&src))),
            "drained source must be released: {effs:?}"
        );
    }

    #[test]
    fn destination_failure_requeues_and_scrubs_cross_state() {
        let mut r = router(DispatchPolicy::FirstCacheAvailable, 2);
        for _ in 0..4 {
            let (_, effs) = r.register_node(Micros::ZERO);
            r.drain_effects(effs, Micros::ZERO);
        }
        let (a, b) = files_on_distinct_shards(&r);
        let effs = r.on_arrival(task(0, &[b]), 0, 0.0, Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
        let (dest, _) = start_cross_fetch(&mut r, 1, a, b);
        // Kill the destination mid-fetch: the task requeues in its own
        // shard and the cross-shard bookkeeping is scrubbed.
        let effs = r.on_executor_failed(dest, Micros::from_millis(1));
        assert_eq!(r.counters().exec_failures, 1);
        assert_eq!(r.node_count(), 3);
        assert_eq!(r.shard_of_exec(dest), None);
        r.check_integrity().unwrap();
        // The replay notifies the surviving home-shard executor; the
        // drain runs it to completion (crossing shards again).
        r.drain_effects(effs, Micros::from_millis(1));
        assert!(r.queue_is_empty());
        assert_eq!(r.counters().cross_fetches, 2);
        r.check_integrity().unwrap();
        let rec = r.take_merged_recorder();
        assert_eq!(rec.tasks_done(), 2);
        // Stale events for the dead executor are no-ops.
        assert!(r.on_pickup(dest, Micros::from_millis(2)).is_empty());
        assert!(r
            .on_executor_failed(dest, Micros::from_millis(2))
            .is_empty());
    }

    #[test]
    fn source_failure_lets_the_fetch_fall_back_to_gpfs() {
        let mut r = router(DispatchPolicy::FirstCacheAvailable, 2);
        for _ in 0..4 {
            let (_, effs) = r.register_node(Micros::ZERO);
            r.drain_effects(effs, Micros::ZERO);
        }
        let (a, b) = files_on_distinct_shards(&r);
        let sa = r.shard_of_file(FileId(a));
        let sb = r.shard_of_file(FileId(b));
        let effs = r.on_arrival(task(0, &[b]), 0, 0.0, Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
        let (_, src) = start_cross_fetch(&mut r, 1, a, b);
        // Kill the serving source mid-transfer: its replicas scrub and
        // its serving refcount dies with it.
        let effs = r.on_executor_failed(src, Micros::from_millis(1));
        assert!(effs.is_empty(), "idle source: nothing to requeue");
        assert_eq!(r.shard_of_exec(src), None);
        assert_eq!(r.core(sb).probe_holder(FileId(b)), None, "replica scrubbed");
        r.check_integrity().unwrap();
        // The destination's driver falls back to persistent storage and
        // reports what it observed — the global-hit override is gone.
        let effs = r.on_fetch_done(TaskId(1), Micros::from_millis(2), Some((AccessKind::Miss, 10)));
        assert!(matches!(effs.as_slice(), [Effect::Compute { .. }]));
        let _ = r.on_compute_done(TaskId(1), Micros::from_millis(3), Micros::from_millis(3));
        r.check_integrity().unwrap();
        assert_eq!(
            r.core(sa).rec.access_counts(),
            (0, 0, 2),
            "both of task 1's accesses ended up as misses"
        );
    }

    #[test]
    fn model_allocation_rebalances_quotas_toward_the_loaded_shard() {
        let mut cfg = config(DispatchPolicy::GoodCacheCompute);
        cfg.provisioner.allocation = AllocationPolicy::Model;
        let mut r = ShardedCoordinator::new(cfg, 2, Pcg64::seeded(3));
        let (a, b) = files_on_distinct_shards(&r);
        let sa = r.shard_of_file(FileId(a));
        let sb = r.shard_of_file(FileId(b));
        assert_eq!(r.core(sa).node_quota() + r.core(sb).node_quota(), 8);
        // All arrival pressure lands on shard A (no executors: the
        // backlog and the recorded arrivals both count as weight).
        for i in 0..12u64 {
            let effs = r.on_arrival(task(i, &[a]), 0, 1.0, Micros::ZERO);
            assert!(effs.is_empty(), "no executors: tasks must queue");
        }
        let _ = r.on_tick(Micros::from_secs(1));
        assert!(r.quota_rebalances() >= 1, "loaded shard must attract quota");
        assert!(
            r.core(sa).node_quota() > r.core(sb).node_quota(),
            "quota follows arrival pressure: {} vs {}",
            r.core(sa).node_quota(),
            r.core(sb).node_quota()
        );
        assert!(r.core(sb).node_quota() >= 1, "idle shard keeps its floor");
        assert_eq!(
            r.core(sa).node_quota() + r.core(sb).node_quota(),
            8,
            "the cluster cap is conserved"
        );
        r.check_integrity().unwrap();
    }

    #[test]
    fn single_shard_model_runs_never_rebalance() {
        let mut cfg = config(DispatchPolicy::GoodCacheCompute);
        cfg.provisioner.allocation = AllocationPolicy::Model;
        let mut r = ShardedCoordinator::new(cfg, 1, Pcg64::seeded(3));
        for i in 0..6u64 {
            let _ = r.on_arrival(task(i, &[0]), 0, 1.0, Micros::ZERO);
        }
        let _ = r.on_tick(Micros::from_secs(1));
        let _ = r.on_tick(Micros::from_secs(2));
        assert_eq!(r.quota_rebalances(), 0, "K = 1 is a pass-through");
        assert_eq!(r.core(0).node_quota(), 8, "single core keeps the full cap");
    }

    #[test]
    fn static_policies_never_rebalance_quotas() {
        let mut r = router(DispatchPolicy::GoodCacheCompute, 4);
        for i in 0..12u64 {
            let _ = r.on_arrival(task(i, &[(i % 3) as u32]), 0, 1.0, Micros::ZERO);
        }
        let _ = r.on_tick(Micros::from_secs(1));
        assert_eq!(r.quota_rebalances(), 0);
        let total: usize = (0..4).map(|s| r.core(s).node_quota()).sum();
        assert_eq!(total, 8, "static quotas stay at the construction split");
    }

    #[test]
    fn stale_task_reports_are_rejected_not_fatal() {
        // Byzantine reports — duplicated or corrupted completions naming
        // tasks that are not in flight — must bounce off the router (or
        // the core, at K = 1) without panicking or perturbing state.
        let mut r = router(DispatchPolicy::GoodCacheCompute, 2);
        for _ in 0..2 {
            let (_, effs) = r.register_node(Micros::ZERO);
            r.drain_effects(effs, Micros::ZERO);
        }
        assert!(r.on_fetch_done(TaskId(99), Micros::ZERO, None).is_empty());
        assert!(r
            .on_compute_done(TaskId(99), Micros::ZERO, Micros::ZERO)
            .is_empty());
        assert!(r.on_task_failed(TaskId(99), Micros::ZERO).is_empty());
        assert_eq!(r.counters().stale_events, 3, "router bounced all three");
        r.check_integrity().unwrap();

        // A real task, then a duplicated completion: the first report
        // retires the routing entry, so the replay is stale.
        let effs = r.on_arrival(task(0, &[3]), 0, 0.0, Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
        assert!(r
            .on_compute_done(TaskId(0), Micros::ZERO, Micros::ZERO)
            .is_empty());
        assert_eq!(r.counters().stale_events, 4);
        let rec = r.take_merged_recorder();
        assert_eq!(rec.tasks_done(), 1, "the duplicate recorded nothing");

        // K = 1 has no routing table: the single core itself rejects.
        let mut r1 = router(DispatchPolicy::GoodCacheCompute, 1);
        assert!(r1.on_fetch_done(TaskId(99), Micros::ZERO, None).is_empty());
        assert_eq!(r1.stale_events(), 1);
        assert_eq!(r1.counters().stale_events, 0, "the core made the call");
        r1.check_integrity().unwrap();
    }

    #[test]
    fn release_drops_id_bindings() {
        let mut r = router(DispatchPolicy::GoodCacheCompute, 2);
        let (e0, effs) = r.register_node(Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
        assert_eq!(r.node_count(), 1);
        r.release_node(e0);
        assert_eq!(r.node_count(), 0);
        assert_eq!(r.shard_of_exec(e0), None);
        // Stale events for the released executor are ignored gracefully.
        assert!(r.on_pickup(e0, Micros::ZERO).is_empty());
        r.release_node(e0); // double release is a no-op
    }
}
