//! The Falkon coordinator: wait queue, executor registry, data-aware
//! scheduler, and dynamic resource provisioner.
//!
//! Everything in this module is *pure decision logic* over explicit state
//! — no clocks, threads, or I/O — so the same code drives both the
//! discrete-event simulator ([`crate::sim`]) and the live thread-pool
//! engine ([`crate::live`]). The engines own time and data movement; the
//! coordinator owns *what happens next*:
//!
//! * [`CoordinatorCore`](self::core::CoordinatorCore) — the shared
//!   dispatch state machine: a typed event API (`on_arrival`,
//!   `on_pickup`, `on_fetch_done`, `on_compute_done`, `on_tick`)
//!   returning [`Effect`](self::core::Effect) lists the engines enact;
//! * [`ShardedCoordinator`](self::shard::ShardedCoordinator) — K cores
//!   behind that same API: the task stream partitioned by dominant-file
//!   hash, executors assigned per shard, GPFS misses rewritten into
//!   cross-shard peer fetches (see `docs/SHARDING.md`). The sim engine
//!   drives this type (K = 1 is a bit-identical pass-through); the parts
//!   below are the cores' internals (still exported for benches, parity
//!   tests and unit composition):
//! * [`queue::WaitQueue`] — the task wait queue (Q) with O(1) window
//!   removal and O(1) window-membership tests;
//! * [`pending::PendingIndex`] — the inverted pending-task index the
//!   sub-linear pickup enumerates instead of scanning the window;
//! * [`executor::ExecutorRegistry`] — E_set with free/busy/pending state;
//! * [`scheduler::Scheduler`] — the two-phase data-aware scheduler;
//! * [`provisioner::Provisioner`] — DRP allocation/release decisions;
//! * [`model::ModelController`] — the §3 model run online: estimates
//!   workload signals from the recorder and installs the performance-
//!   index-maximizing fleet target (`--allocation model`,
//!   docs/PROVISIONING.md).

pub mod core;
pub mod executor;
pub mod model;
pub mod pending;
pub mod provisioner;
pub mod queue;
pub mod scheduler;
pub mod shard;

use crate::cache::ObjectCache;
#[cfg(test)]
use crate::cache::CacheConfig;
use crate::ids::{ExecutorId, FileId};
use crate::index::LocationIndex;
use crate::util::prng::Pcg64;

/// Classification of one file access — the paper's three-way split that
/// every cache/throughput figure is built on (§5.2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccessKind {
    /// Served from the executor's local cache (H_L).
    HitLocal,
    /// Fetched from a peer executor's cache (H_C, "global hit").
    HitGlobal,
    /// Fetched from persistent storage / GPFS (H_S, miss).
    Miss,
}

/// Outcome of resolving one file access on the task data path.
#[derive(Debug, Clone)]
pub struct AccessResolution {
    /// Local hit / global (peer) hit / persistent-store miss.
    pub kind: AccessKind,
    /// For global hits, the peer executor chosen as the transfer source.
    pub peer: Option<ExecutorId>,
    /// Files evicted from the executor's cache to make room (the live
    /// engine deletes these from the worker's cache directory).
    pub evicted: Vec<FileId>,
    /// Did the file enter the executor's cache (⇒ a `LocationIndex::add`
    /// happened)? False for local hits (already resident) and for
    /// objects larger than the whole cache. Engines use this plus
    /// `evicted` to keep the [`pending::PendingIndex`] coherent.
    pub inserted: bool,
}

/// Shared helper: resolve where an executor will get `file` from and
/// update cache + index accordingly.
///
/// The peer for a global hit is picked uniformly at random among holders
/// to spread load, like Falkon's GridFTP peer selection. This is the
/// single place where cache contents and the central index are mutated
/// on the task data path, keeping the two coherent in both engines.
pub fn resolve_access(
    exec: ExecutorId,
    file: FileId,
    size: u64,
    cache: &mut ObjectCache,
    index: &mut LocationIndex,
    rng: &mut Pcg64,
) -> AccessResolution {
    if cache.touch(file) {
        return AccessResolution {
            kind: AccessKind::HitLocal,
            peer: None,
            evicted: Vec::new(),
            inserted: false,
        };
    }
    // Pick a peer holder if any (excluding ourselves, which we know
    // misses). The holder bitset iterates in ascending id order (as the
    // old sorted set did), so the k-th-peer draw is bit-identical.
    let peer = index.holders(file).and_then(|holders| {
        let peers = holders.len() - usize::from(holders.contains(exec));
        if peers == 0 {
            None
        } else {
            let k = rng.below(peers as u64) as usize;
            holders.iter().filter(|&e| e != exec).nth(k)
        }
    });
    // Insert into our cache (evicting as needed) and update the index.
    let mut evicted_files = Vec::new();
    let mut inserted = false;
    if let Some(evicted) = cache.insert(file, size, rng) {
        for &old in &evicted {
            index.remove(old, exec);
        }
        index.add(file, exec);
        evicted_files = evicted;
        inserted = true;
    }
    AccessResolution {
        kind: if peer.is_some() {
            AccessKind::HitGlobal
        } else {
            AccessKind::Miss
        },
        peer,
        evicted: evicted_files,
        inserted,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::EvictionPolicy;

    fn cache(cap: u64) -> ObjectCache {
        ObjectCache::new(CacheConfig {
            capacity_bytes: cap,
            policy: EvictionPolicy::Lru,
        })
    }

    #[test]
    fn miss_then_local_hit() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(100);
        let mut ix = LocationIndex::new();
        let r = resolve_access(ExecutorId(0), FileId(1), 10, &mut c, &mut ix, &mut rng);
        assert_eq!(r.kind, AccessKind::Miss);
        assert_eq!(r.peer, None);
        assert_eq!(ix.replication(FileId(1)), 1);
        let r = resolve_access(ExecutorId(0), FileId(1), 10, &mut c, &mut ix, &mut rng);
        assert_eq!(r.kind, AccessKind::HitLocal);
    }

    #[test]
    fn global_hit_from_peer() {
        let mut rng = Pcg64::seeded(1);
        let mut c0 = cache(100);
        let mut c1 = cache(100);
        let mut ix = LocationIndex::new();
        resolve_access(ExecutorId(0), FileId(1), 10, &mut c0, &mut ix, &mut rng);
        let r = resolve_access(ExecutorId(1), FileId(1), 10, &mut c1, &mut ix, &mut rng);
        assert_eq!(r.kind, AccessKind::HitGlobal);
        assert_eq!(r.peer, Some(ExecutorId(0)));
        assert_eq!(ix.replication(FileId(1)), 2);
    }

    #[test]
    fn eviction_updates_index() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(20);
        let mut ix = LocationIndex::new();
        resolve_access(ExecutorId(0), FileId(1), 15, &mut c, &mut ix, &mut rng);
        let r = resolve_access(ExecutorId(0), FileId(2), 15, &mut c, &mut ix, &mut rng);
        assert_eq!(r.evicted, vec![FileId(1)]);
        assert_eq!(ix.replication(FileId(1)), 0, "evicted file left the index");
        assert_eq!(ix.replication(FileId(2)), 1);
        ix.check_consistent().unwrap();
    }

    #[test]
    fn oversized_file_is_miss_without_caching() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(5);
        let mut ix = LocationIndex::new();
        let r = resolve_access(ExecutorId(0), FileId(1), 10, &mut c, &mut ix, &mut rng);
        assert_eq!(r.kind, AccessKind::Miss);
        assert_eq!(ix.replication(FileId(1)), 0);
        assert!(c.is_empty());
    }
}
