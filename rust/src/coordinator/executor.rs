//! Executor registry — the scheduler's E_set (§3.2).
//!
//! Tracks every registered executor (one per provisioned node; each has
//! `cpus` task slots, 2 in the paper's testbed) and its state: *free*
//! (≥1 idle slot), *busy* (all slots running tasks), or *pending* (a
//! dispatch notification is in flight, §3.2's pending state). The free
//! set is an ordered set so "next free executor" is deterministic.

use crate::ids::ExecutorId;
use crate::util::time::Micros;
use std::collections::{BTreeSet, HashMap};

/// Per-executor registry entry.
#[derive(Debug, Clone)]
pub struct ExecutorEntry {
    /// Total task slots (CPUs).
    pub slots: u32,
    /// Slots currently running tasks.
    pub busy_slots: u32,
    /// Slots reserved by in-flight dispatch notifications.
    pub pending_slots: u32,
    /// Time this executor last started or finished a task (idle-release
    /// accounting in the provisioner).
    pub last_active: Micros,
    /// Registration time.
    pub registered_at: Micros,
}

impl ExecutorEntry {
    /// Slots with neither work nor a pending notification.
    pub fn free_slots(&self) -> u32 {
        self.slots - self.busy_slots - self.pending_slots
    }
}

/// Registry of all executors with free/busy/pending accounting.
#[derive(Debug, Default)]
pub struct ExecutorRegistry {
    entries: HashMap<ExecutorId, ExecutorEntry>,
    /// Executors with ≥1 free slot, ordered for deterministic iteration.
    free: BTreeSet<ExecutorId>,
    /// Ids of deregistered executors, recycled LIFO so the id space stays
    /// dense under DRP allocate/release churn — the executor bitsets
    /// ([`crate::index::ExecSet`]) are sized by the peak id, so recycling
    /// keeps them at O(peak concurrent nodes / 64) words for the lifetime
    /// of a run. Deregistration fully scrubs an executor's state (caches,
    /// links, index entries, pending candidates), so a recycled id can
    /// never alias stale references.
    recycled_ids: Vec<u32>,
    total_slots: u64,
    busy_slots: u64,
    next_id: u32,
}

impl ExecutorRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a newly provisioned executor with `slots` CPUs; returns
    /// its id (a recycled one if an earlier executor was released, else
    /// fresh — keeping the id space dense for the executor bitsets).
    pub fn register(&mut self, slots: u32, now: Micros) -> ExecutorId {
        assert!(slots > 0);
        let id = match self.recycled_ids.pop() {
            Some(i) => ExecutorId(i),
            None => {
                let id = ExecutorId(self.next_id);
                self.next_id += 1;
                id
            }
        };
        self.entries.insert(
            id,
            ExecutorEntry {
                slots,
                busy_slots: 0,
                pending_slots: 0,
                last_active: now,
                registered_at: now,
            },
        );
        self.free.insert(id);
        self.total_slots += slots as u64;
        id
    }

    /// Deregister (release) an executor. Panics if it still has busy or
    /// pending slots — the provisioner must only release idle executors.
    pub fn deregister(&mut self, id: ExecutorId) -> ExecutorEntry {
        let entry = self.entries.remove(&id).expect("unknown executor");
        assert_eq!(entry.busy_slots, 0, "releasing busy executor {id}");
        assert_eq!(entry.pending_slots, 0, "releasing pending executor {id}");
        self.free.remove(&id);
        self.total_slots -= entry.slots as u64;
        self.recycled_ids.push(id.0);
        entry
    }

    /// Forcibly remove a **failed** executor, busy or not — the crash
    /// path [`deregister`](ExecutorRegistry::deregister) refuses. Slots
    /// the dead node was running or holding pending vanish with it (the
    /// caller requeues the affected tasks per the §4.2 replay policy);
    /// aggregate slot counters are corrected accordingly. Returns the
    /// removed entry for accounting.
    pub fn fail(&mut self, id: ExecutorId) -> ExecutorEntry {
        let entry = self.entries.remove(&id).expect("unknown executor");
        self.free.remove(&id);
        self.total_slots -= entry.slots as u64;
        self.busy_slots -= entry.busy_slots as u64;
        self.recycled_ids.push(id.0);
        entry
    }

    /// Look up an executor.
    pub fn get(&self, id: ExecutorId) -> Option<&ExecutorEntry> {
        self.entries.get(&id)
    }

    /// Is this executor registered?
    pub fn contains(&self, id: ExecutorId) -> bool {
        self.entries.contains_key(&id)
    }

    /// Does `id` have a free slot (registered, not all busy/pending)?
    pub fn is_free(&self, id: ExecutorId) -> bool {
        self.free.contains(&id)
    }

    /// First free executor at-or-after `from` in id order, wrapping —
    /// the paper's "next free executor" fallback, kept rotating so
    /// first-available load-balances instead of pinning executor 0.
    pub fn next_free(&self, from: ExecutorId) -> Option<ExecutorId> {
        self.free
            .range(from..)
            .next()
            .or_else(|| self.free.iter().next())
            .copied()
    }

    /// Iterate all free executors in id order.
    pub fn free_iter(&self) -> impl Iterator<Item = ExecutorId> + '_ {
        self.free.iter().copied()
    }

    /// Number of free executors.
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Reserve a slot for an in-flight dispatch (pending state).
    pub fn mark_pending(&mut self, id: ExecutorId) {
        let e = self.entries.get_mut(&id).expect("unknown executor");
        assert!(e.free_slots() > 0, "no free slot to mark pending on {id}");
        e.pending_slots += 1;
        if e.free_slots() == 0 {
            self.free.remove(&id);
        }
    }

    /// Convert a pending reservation into a running task.
    pub fn pending_to_busy(&mut self, id: ExecutorId, now: Micros) {
        let e = self.entries.get_mut(&id).expect("unknown executor");
        assert!(e.pending_slots > 0, "no pending slot on {id}");
        e.pending_slots -= 1;
        e.busy_slots += 1;
        e.last_active = now;
        self.busy_slots += 1;
    }

    /// Cancel a pending reservation (notification declined / no work).
    pub fn cancel_pending(&mut self, id: ExecutorId) {
        let e = self.entries.get_mut(&id).expect("unknown executor");
        assert!(e.pending_slots > 0, "no pending slot on {id}");
        e.pending_slots -= 1;
        self.free.insert(id);
    }

    /// Start a task directly on a free slot (no notification round-trip).
    pub fn start_task(&mut self, id: ExecutorId, now: Micros) {
        let e = self.entries.get_mut(&id).expect("unknown executor");
        assert!(e.free_slots() > 0, "no free slot on {id}");
        e.busy_slots += 1;
        e.last_active = now;
        self.busy_slots += 1;
        if e.free_slots() == 0 {
            self.free.remove(&id);
        }
    }

    /// Finish a task, freeing its slot.
    pub fn finish_task(&mut self, id: ExecutorId, now: Micros) {
        let e = self.entries.get_mut(&id).expect("unknown executor");
        assert!(e.busy_slots > 0, "finish with no busy slot on {id}");
        e.busy_slots -= 1;
        e.last_active = now;
        self.busy_slots -= 1;
        self.free.insert(id);
    }

    /// Registered executor count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no executors are registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total slots across the cluster.
    pub fn total_slots(&self) -> u64 {
        self.total_slots
    }

    /// Busy slots across the cluster.
    pub fn busy_slots(&self) -> u64 {
        self.busy_slots
    }

    /// CPU utilization in [0, 1] — the good-cache-compute heuristic input
    /// ("number of busy nodes divided by all registered nodes", §3.2; we
    /// use slots for a smoother signal with 2 CPUs/node).
    pub fn cpu_utilization(&self) -> f64 {
        if self.total_slots == 0 {
            0.0
        } else {
            self.busy_slots as f64 / self.total_slots as f64
        }
    }

    /// Iterate `(id, entry)` pairs in arbitrary order.
    pub fn iter(&self) -> impl Iterator<Item = (ExecutorId, &ExecutorEntry)> {
        self.entries.iter().map(|(&id, e)| (id, e))
    }

    /// Executors idle since before `cutoff` (provisioner release scan).
    pub fn idle_since(&self, cutoff: Micros) -> Vec<ExecutorId> {
        let mut v: Vec<ExecutorId> = self
            .entries
            .iter()
            .filter(|(_, e)| {
                e.busy_slots == 0 && e.pending_slots == 0 && e.last_active < cutoff
            })
            .map(|(&id, _)| id)
            .collect();
        v.sort_unstable();
        v
    }

    /// Internal consistency check (tests).
    #[doc(hidden)]
    pub fn check_consistent(&self) -> Result<(), String> {
        let mut busy = 0u64;
        let mut total = 0u64;
        for (id, e) in &self.entries {
            if e.busy_slots + e.pending_slots > e.slots {
                return Err(format!("{id}: overcommitted"));
            }
            let should_be_free = e.free_slots() > 0;
            if should_be_free != self.free.contains(id) {
                return Err(format!("{id}: free set disagrees"));
            }
            busy += e.busy_slots as u64;
            total += e.slots as u64;
        }
        if busy != self.busy_slots || total != self.total_slots {
            return Err("aggregate slot counters drifted".into());
        }
        for id in &self.free {
            if !self.entries.contains_key(id) {
                return Err(format!("{id} in free set but not registered"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_free_busy_pending() {
        let mut reg = ExecutorRegistry::new();
        let e = reg.register(2, Micros::ZERO);
        assert!(reg.is_free(e));
        reg.start_task(e, Micros::from_secs(1));
        assert!(reg.is_free(e)); // 1 of 2 slots busy
        reg.mark_pending(e);
        assert!(!reg.is_free(e)); // busy + pending = 2
        reg.pending_to_busy(e, Micros::from_secs(2));
        assert_eq!(reg.cpu_utilization(), 1.0);
        reg.finish_task(e, Micros::from_secs(3));
        reg.finish_task(e, Micros::from_secs(3));
        assert_eq!(reg.cpu_utilization(), 0.0);
        reg.check_consistent().unwrap();
    }

    #[test]
    fn next_free_rotates() {
        let mut reg = ExecutorRegistry::new();
        let ids: Vec<_> = (0..3).map(|_| reg.register(1, Micros::ZERO)).collect();
        assert_eq!(reg.next_free(ids[1]), Some(ids[1]));
        reg.start_task(ids[1], Micros::ZERO);
        assert_eq!(reg.next_free(ids[1]), Some(ids[2]));
        reg.start_task(ids[2], Micros::ZERO);
        // Wraps around.
        assert_eq!(reg.next_free(ids[1]), Some(ids[0]));
        reg.start_task(ids[0], Micros::ZERO);
        assert_eq!(reg.next_free(ids[1]), None);
    }

    #[test]
    fn cancel_pending_restores_free() {
        let mut reg = ExecutorRegistry::new();
        let e = reg.register(1, Micros::ZERO);
        reg.mark_pending(e);
        assert!(!reg.is_free(e));
        reg.cancel_pending(e);
        assert!(reg.is_free(e));
        reg.check_consistent().unwrap();
    }

    #[test]
    #[should_panic(expected = "releasing busy executor")]
    fn cannot_release_busy() {
        let mut reg = ExecutorRegistry::new();
        let e = reg.register(1, Micros::ZERO);
        reg.start_task(e, Micros::ZERO);
        reg.deregister(e);
    }

    #[test]
    fn fail_removes_busy_executor_and_fixes_slot_sums() {
        let mut reg = ExecutorRegistry::new();
        let a = reg.register(2, Micros::ZERO);
        let b = reg.register(2, Micros::ZERO);
        reg.start_task(a, Micros::ZERO);
        reg.mark_pending(a);
        assert_eq!(reg.total_slots(), 4);
        assert_eq!(reg.busy_slots(), 1);
        // deregister() would panic here; fail() force-removes.
        let entry = reg.fail(a);
        assert_eq!(entry.busy_slots, 1);
        assert_eq!(entry.pending_slots, 1);
        assert!(!reg.contains(a));
        assert_eq!(reg.total_slots(), 2);
        assert_eq!(reg.busy_slots(), 0);
        assert!(reg.contains(b));
        reg.check_consistent().unwrap();
        // The dead id is recycled like a released one.
        let c = reg.register(1, Micros::ZERO);
        assert_eq!(c, a);
        reg.check_consistent().unwrap();
    }

    #[test]
    fn deregistered_ids_are_recycled() {
        let mut reg = ExecutorRegistry::new();
        let a = reg.register(1, Micros::ZERO);
        let b = reg.register(1, Micros::ZERO);
        reg.deregister(a);
        let c = reg.register(2, Micros::ZERO);
        assert_eq!(c, a, "released id must be reused (dense id space)");
        assert!(reg.contains(b) && reg.contains(c));
        assert_eq!(reg.total_slots(), 3);
        reg.check_consistent().unwrap();
    }

    #[test]
    fn idle_since_finds_only_idle() {
        let mut reg = ExecutorRegistry::new();
        let a = reg.register(1, Micros::ZERO);
        let b = reg.register(1, Micros::ZERO);
        reg.start_task(b, Micros::from_secs(100));
        // a idle since 0; b busy.
        assert_eq!(reg.idle_since(Micros::from_secs(50)), vec![a]);
        reg.finish_task(b, Micros::from_secs(100));
        assert_eq!(reg.idle_since(Micros::from_secs(50)), vec![a]);
        assert_eq!(
            reg.idle_since(Micros::from_secs(101)),
            vec![a, b]
        );
    }

    #[test]
    fn registry_invariants_under_random_ops() {
        use crate::util::proptest::{property, Gen};
        property("registry invariants", 80, |g: &mut Gen| {
            let mut reg = ExecutorRegistry::new();
            // (id, busy, pending) shadow model
            let mut shadow: Vec<(ExecutorId, u32, u32, u32)> = Vec::new();
            for step in 0..g.usize_in(1..150) {
                let now = Micros::from_secs(step as u64);
                match g.usize_in(0..6) {
                    0 => {
                        let slots = g.u64_in(1..4) as u32;
                        let id = reg.register(slots, now);
                        shadow.push((id, slots, 0, 0));
                    }
                    1 if !shadow.is_empty() => {
                        let i = g.usize_in(0..shadow.len());
                        let (id, slots, busy, pend) = shadow[i];
                        if busy + pend < slots {
                            reg.start_task(id, now);
                            shadow[i].2 += 1;
                        }
                    }
                    2 if !shadow.is_empty() => {
                        let i = g.usize_in(0..shadow.len());
                        let (id, _, busy, _) = shadow[i];
                        if busy > 0 {
                            reg.finish_task(id, now);
                            shadow[i].2 -= 1;
                        }
                    }
                    3 if !shadow.is_empty() => {
                        let i = g.usize_in(0..shadow.len());
                        let (id, slots, busy, pend) = shadow[i];
                        if busy + pend < slots {
                            reg.mark_pending(id);
                            shadow[i].3 += 1;
                        }
                    }
                    4 if !shadow.is_empty() => {
                        let i = g.usize_in(0..shadow.len());
                        let (id, _, _, pend) = shadow[i];
                        if pend > 0 {
                            if g.bool(0.5) {
                                reg.pending_to_busy(id, now);
                                shadow[i].2 += 1;
                            } else {
                                reg.cancel_pending(id);
                            }
                            shadow[i].3 -= 1;
                        }
                    }
                    5 if !shadow.is_empty() => {
                        let i = g.usize_in(0..shadow.len());
                        let (id, _, busy, pend) = shadow[i];
                        if busy == 0 && pend == 0 {
                            reg.deregister(id);
                            shadow.swap_remove(i);
                        }
                    }
                    _ => {}
                }
                reg.check_consistent()?;
            }
            Ok(())
        });
    }
}
