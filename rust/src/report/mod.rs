//! Report rendering: ASCII tables on stdout plus CSV files under
//! `target/figures/`, one per regenerated paper figure, so every number
//! quoted in EXPERIMENTS.md is traceable to a file.

use std::io::Write;
use std::path::PathBuf;

/// A simple column-aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table title (printed as a header).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Rows of cells (must match header arity).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (panics on arity mismatch — a bug in the caller).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n### {}\n", self.title));
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{:>w$}", c, w = widths[i]));
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
        let _ = std::io::stdout().flush();
    }

    /// Write as CSV under `target/figures/<name>.csv`; returns the path.
    pub fn write_csv(&self, name: &str) -> std::io::Result<PathBuf> {
        let dir = PathBuf::from("target/figures");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut out = String::new();
        out.push_str(&csv_row(&self.headers));
        for row in &self.rows {
            out.push_str(&csv_row(row));
        }
        std::fs::write(&path, out)?;
        Ok(path)
    }
}

fn csv_row(cells: &[String]) -> String {
    let quoted: Vec<String> = cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect();
    format!("{}\n", quoted.join(","))
}

/// Format a float with `d` decimals.
pub fn f(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

/// Format a fraction as a percentage with one decimal.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "12345".into()]);
        let s = t.render();
        assert!(s.contains("### demo"));
        assert!(s.contains("alpha"));
        assert!(s.contains("12345"));
        // Aligned: both rows same width.
        let lines: Vec<&str> = s.lines().filter(|l| !l.is_empty()).collect();
        assert_eq!(lines[2].len(), lines[4].len().max(lines[3].len()));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        assert_eq!(csv_row(&["a,b".into(), "c\"d".into()]), "\"a,b\",\"c\"\"d\"\n");
        assert_eq!(csv_row(&["plain".into()]), "plain\n");
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.345), "34.5%");
    }
}
