//! Per-shard recorder snapshots as JSON-lines envelopes — the file-based
//! shard transport of ROADMAP item 1 (`figures --emit-shards DIR` /
//! `figures --merge DIR`, docs/LIVE.md).
//!
//! One envelope holds one shard's [`Recorder`] plus run identity
//! ([`SnapshotMeta`]). The format is designed for *bit-exact* round
//! trips, not human editing:
//!
//! * every `f64` travels as its IEEE-754 bit pattern
//!   ([`f64::to_bits`]) printed as a decimal `u64` — no decimal
//!   formatting, no parsing drift;
//! * every time-series bucket is written, including all-zero ones, so
//!   the merged series length (and therefore every gauge sum and the
//!   re-derived queue peak) is identical to the in-process merge;
//! * a trailing `end` record carries the line count, so truncated files
//!   fail loudly instead of merging a partial shard.
//!
//! Schema (one JSON object per line, `u64` integers and escape-free
//! strings only):
//!
//! ```text
//! {"schema":1,"kind":"meta","run":"fig05-...","shard":0,"shards":4,
//!  "ideal_wet_bits":...,"hits_local":...,"hits_global":...,"misses":...,
//!  "tasks_done":...,"resp_sum_bits":...,"resp_max_bits":...,
//!  "last_completion_us":...,"cpu_slot_seconds_bits":...,"queue_max":...,
//!  "buckets":N,"intervals":M}
//! {"kind":"bucket","sec":0,"bl":..,"br":..,"bg":..,"tc":..,"ar":..,
//!  "ql":..,"no":..,"bs":..,"ts":..}            × N (sequential)
//! {"kind":"interval","idx":0,"rate_bits":..,"start_us":..,
//!  "last_arrival_us":..,"last_completion_us":..,"tasks":..}   × M
//! {"kind":"end","lines":1+N+M}
//! ```
//!
//! Any malformed line surfaces as a typed
//! [`ConfigError::InvalidValue`] naming the line, and a missing `end`
//! record as [`ConfigError::MissingKey`] — never a panic (the merge
//! round-trip test in `integration.rs` pins both).

use std::fmt::Write as _;

use crate::config::ConfigError;
use crate::util::time::Micros;
use crate::{Error, Result};

use super::{IntervalStat, Recorder};

/// Envelope schema version.
pub const SCHEMA: u64 = 1;

/// Run identity carried alongside one shard's recorder.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotMeta {
    /// Run name (the experiment config's `name`); merge groups by this.
    pub run: String,
    /// Shard id of this snapshot (0-based).
    pub shard: usize,
    /// Total shards in the run this snapshot belongs to.
    pub shards: usize,
    /// Ideal workload execution time (s) — identical across the run's
    /// shards; the merge re-summarizes against it.
    pub ideal_wet_s: f64,
}

/// Serialize one shard's recorder into a JSON-lines envelope.
pub fn to_jsonl(meta: &SnapshotMeta, rec: &Recorder) -> String {
    let buckets = rec.ts.buckets();
    let mut out = String::new();
    let mut lines = 0usize;
    let _ = writeln!(
        out,
        "{{\"schema\":{SCHEMA},\"kind\":\"meta\",\"run\":\"{}\",\"shard\":{},\
         \"shards\":{},\"ideal_wet_bits\":{},\"hits_local\":{},\"hits_global\":{},\
         \"misses\":{},\"tasks_done\":{},\"resp_sum_bits\":{},\"resp_max_bits\":{},\
         \"last_completion_us\":{},\"cpu_slot_seconds_bits\":{},\"queue_max\":{},\
         \"buckets\":{},\"intervals\":{}}}",
        meta.run,
        meta.shard,
        meta.shards,
        meta.ideal_wet_s.to_bits(),
        rec.hits_local,
        rec.hits_global,
        rec.misses,
        rec.tasks_done,
        rec.resp_sum_s.to_bits(),
        rec.resp_max_s.to_bits(),
        rec.last_completion.0,
        rec.cpu_slot_seconds.to_bits(),
        rec.queue_max,
        buckets.len(),
        rec.intervals.len(),
    );
    lines += 1;
    for (sec, b) in buckets.iter().enumerate() {
        let _ = writeln!(
            out,
            "{{\"kind\":\"bucket\",\"sec\":{sec},\"bl\":{},\"br\":{},\"bg\":{},\
             \"tc\":{},\"ar\":{},\"ql\":{},\"no\":{},\"bs\":{},\"ts\":{}}}",
            b.bytes_local,
            b.bytes_remote,
            b.bytes_gpfs,
            b.tasks_completed,
            b.arrivals,
            b.queue_len,
            b.nodes,
            b.busy_slots,
            b.total_slots,
        );
        lines += 1;
    }
    for (idx, iv) in rec.intervals.iter().enumerate() {
        let _ = writeln!(
            out,
            "{{\"kind\":\"interval\",\"idx\":{idx},\"rate_bits\":{},\"start_us\":{},\
             \"last_arrival_us\":{},\"last_completion_us\":{},\"tasks\":{}}}",
            iv.rate.to_bits(),
            iv.start.0,
            iv.last_arrival.0,
            iv.last_completion.0,
            iv.tasks,
        );
        lines += 1;
    }
    let _ = writeln!(out, "{{\"kind\":\"end\",\"lines\":{lines}}}");
    out
}

/// Parse an envelope back into its meta + recorder. Bit-exact inverse of
/// [`to_jsonl`]; every failure is a typed [`ConfigError`].
pub fn from_jsonl(text: &str) -> Result<(SnapshotMeta, Recorder)> {
    let mut meta: Option<SnapshotMeta> = None;
    let mut rec = Recorder::default();
    let mut want_buckets = 0usize;
    let mut want_intervals = 0usize;
    let mut got_buckets = 0usize;
    let mut got_intervals = 0usize;
    let mut body_lines = 0usize;
    let mut ended = false;

    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let lineno = i + 1;
        if ended {
            return Err(bad(lineno, line, "no content after the `end` record"));
        }
        let fields = parse_obj(line, lineno)?;
        let kind = get_str(&fields, "kind", lineno, line)?;
        match kind.as_str() {
            "meta" => {
                if meta.is_some() {
                    return Err(bad(lineno, line, "a single `meta` record"));
                }
                let schema = get_u64(&fields, "schema", lineno, line)?;
                if schema != SCHEMA {
                    return Err(bad(lineno, line, &format!("schema {SCHEMA}")));
                }
                meta = Some(SnapshotMeta {
                    run: get_str(&fields, "run", lineno, line)?,
                    shard: get_u64(&fields, "shard", lineno, line)? as usize,
                    shards: get_u64(&fields, "shards", lineno, line)?.max(1) as usize,
                    ideal_wet_s: f64::from_bits(get_u64(&fields, "ideal_wet_bits", lineno, line)?),
                });
                rec.hits_local = get_u64(&fields, "hits_local", lineno, line)?;
                rec.hits_global = get_u64(&fields, "hits_global", lineno, line)?;
                rec.misses = get_u64(&fields, "misses", lineno, line)?;
                rec.tasks_done = get_u64(&fields, "tasks_done", lineno, line)?;
                rec.resp_sum_s = f64::from_bits(get_u64(&fields, "resp_sum_bits", lineno, line)?);
                rec.resp_max_s = f64::from_bits(get_u64(&fields, "resp_max_bits", lineno, line)?);
                rec.last_completion =
                    Micros(get_u64(&fields, "last_completion_us", lineno, line)?);
                rec.cpu_slot_seconds =
                    f64::from_bits(get_u64(&fields, "cpu_slot_seconds_bits", lineno, line)?);
                rec.queue_max = get_u64(&fields, "queue_max", lineno, line)? as usize;
                want_buckets = get_u64(&fields, "buckets", lineno, line)? as usize;
                want_intervals = get_u64(&fields, "intervals", lineno, line)? as usize;
                body_lines += 1;
            }
            "bucket" => {
                if meta.is_none() {
                    return Err(bad(lineno, line, "the `meta` record first"));
                }
                let sec = get_u64(&fields, "sec", lineno, line)? as usize;
                if sec != got_buckets {
                    return Err(bad(lineno, line, &format!("bucket sec {got_buckets}")));
                }
                let b = rec.ts.bucket_mut(sec as u64);
                b.bytes_local = get_u64(&fields, "bl", lineno, line)?;
                b.bytes_remote = get_u64(&fields, "br", lineno, line)?;
                b.bytes_gpfs = get_u64(&fields, "bg", lineno, line)?;
                b.tasks_completed = get_u32(&fields, "tc", lineno, line)?;
                b.arrivals = get_u32(&fields, "ar", lineno, line)?;
                b.queue_len = get_u32(&fields, "ql", lineno, line)?;
                b.nodes = get_u32(&fields, "no", lineno, line)?;
                b.busy_slots = get_u32(&fields, "bs", lineno, line)?;
                b.total_slots = get_u32(&fields, "ts", lineno, line)?;
                got_buckets += 1;
                body_lines += 1;
            }
            "interval" => {
                if meta.is_none() {
                    return Err(bad(lineno, line, "the `meta` record first"));
                }
                let idx = get_u64(&fields, "idx", lineno, line)? as usize;
                if idx != got_intervals {
                    return Err(bad(lineno, line, &format!("interval idx {got_intervals}")));
                }
                rec.intervals.push(IntervalStat {
                    rate: f64::from_bits(get_u64(&fields, "rate_bits", lineno, line)?),
                    start: Micros(get_u64(&fields, "start_us", lineno, line)?),
                    last_arrival: Micros(get_u64(&fields, "last_arrival_us", lineno, line)?),
                    last_completion: Micros(get_u64(
                        &fields,
                        "last_completion_us",
                        lineno,
                        line,
                    )?),
                    tasks: get_u64(&fields, "tasks", lineno, line)?,
                });
                got_intervals += 1;
                body_lines += 1;
            }
            "end" => {
                let n = get_u64(&fields, "lines", lineno, line)? as usize;
                if n != body_lines {
                    return Err(bad(
                        lineno,
                        line,
                        &format!("{body_lines} body line(s) before `end`"),
                    ));
                }
                ended = true;
            }
            other => {
                return Err(bad(
                    lineno,
                    line,
                    &format!("kind meta|bucket|interval|end, not `{other}`"),
                ));
            }
        }
    }

    let meta = meta.ok_or_else(|| truncated("meta"))?;
    if !ended {
        return Err(truncated("end"));
    }
    if got_buckets != want_buckets || got_intervals != want_intervals {
        return Err(Error::Config(ConfigError::Invariant {
            field: "snapshot".into(),
            message: format!(
                "meta promised {want_buckets} bucket(s)/{want_intervals} interval(s), \
                 got {got_buckets}/{got_intervals}"
            ),
        }));
    }
    Ok((meta, rec))
}

fn truncated(key: &str) -> Error {
    Error::Config(ConfigError::MissingKey {
        key: key.into(),
        context: "snapshot envelope (truncated?)".into(),
    })
}

fn bad(lineno: usize, line: &str, expected: &str) -> Error {
    let mut excerpt: String = line.chars().take(60).collect();
    if line.chars().count() > 60 {
        excerpt.push('…');
    }
    Error::Config(ConfigError::InvalidValue {
        key: format!("snapshot line {lineno}"),
        value: excerpt,
        expected: expected.into(),
    })
}

/// One parsed value: the schema only carries `u64` integers and
/// escape-free strings.
enum Field {
    U64(u64),
    Str(String),
}

/// Parse one flat JSON object line into key/value pairs. Hand-rolled on
/// purpose — the crate is zero-dependency, and restricting the grammar
/// (no nesting, no escapes, no floats) keeps the round trip bit-exact.
fn parse_obj(line: &str, lineno: usize) -> Result<Vec<(String, Field)>> {
    let mut cs = line.chars().peekable();
    let mut out = Vec::new();
    if cs.next() != Some('{') {
        return Err(bad(lineno, line, "a `{`-opened JSON object"));
    }
    loop {
        if cs.next() != Some('"') {
            return Err(bad(lineno, line, "a quoted key"));
        }
        let mut key = String::new();
        loop {
            match cs.next() {
                Some('"') => break,
                Some('\\') | None => return Err(bad(lineno, line, "an escape-free key")),
                Some(c) => key.push(c),
            }
        }
        if cs.next() != Some(':') {
            return Err(bad(lineno, line, "`:` after the key"));
        }
        let field = match cs.peek() {
            Some('"') => {
                cs.next();
                let mut s = String::new();
                loop {
                    match cs.next() {
                        Some('"') => break,
                        Some('\\') | None => {
                            return Err(bad(lineno, line, "an escape-free string value"))
                        }
                        Some(c) => s.push(c),
                    }
                }
                Field::Str(s)
            }
            Some(c) if c.is_ascii_digit() => {
                let mut n: u64 = 0;
                while let Some(&d) = cs.peek() {
                    let Some(digit) = d.to_digit(10) else { break };
                    cs.next();
                    n = n
                        .checked_mul(10)
                        .and_then(|n| n.checked_add(u64::from(digit)))
                        .ok_or_else(|| bad(lineno, line, "a u64 integer"))?;
                }
                Field::U64(n)
            }
            _ => return Err(bad(lineno, line, "a string or u64 value")),
        };
        out.push((key, field));
        match cs.next() {
            Some(',') => continue,
            Some('}') => break,
            _ => return Err(bad(lineno, line, "`,` or `}` after a value")),
        }
    }
    if cs.next().is_some() {
        return Err(bad(lineno, line, "nothing after the closing `}`"));
    }
    Ok(out)
}

fn get_u64(fields: &[(String, Field)], key: &str, lineno: usize, line: &str) -> Result<u64> {
    match fields.iter().find(|(k, _)| k == key) {
        Some((_, Field::U64(n))) => Ok(*n),
        _ => Err(bad(lineno, line, &format!("integer field `{key}`"))),
    }
}

fn get_u32(fields: &[(String, Field)], key: &str, lineno: usize, line: &str) -> Result<u32> {
    let n = get_u64(fields, key, lineno, line)?;
    u32::try_from(n).map_err(|_| bad(lineno, line, &format!("u32 field `{key}`")))
}

fn get_str(fields: &[(String, Field)], key: &str, lineno: usize, line: &str) -> Result<String> {
    match fields.iter().find(|(k, _)| k == key) {
        Some((_, Field::Str(s))) => Ok(s.clone()),
        _ => Err(bad(lineno, line, &format!("string field `{key}`"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::AccessKind;

    fn fixture() -> Recorder {
        let mut r = Recorder::new();
        r.record_arrival(Micros::from_secs(0), 0, 0.1 + 0.2); // non-representable rate
        r.record_arrival(Micros::from_secs(2), 1, 7.5);
        r.record_access(Micros::from_secs(1), AccessKind::HitLocal, 100);
        r.record_access(Micros::from_secs(1), AccessKind::HitGlobal, 40);
        r.record_access(Micros::from_secs(3), AccessKind::Miss, 55);
        r.record_completion(Micros(3_333_333), Micros::from_secs(0), 0);
        r.record_completion(Micros(4_000_001), Micros::from_secs(2), 1);
        r.sample(Micros::from_secs(1), 7, 2, 1, 4);
        r.sample(Micros::from_secs(5), 0, 2, 0, 4); // trailing all-zero gauge tail
        r
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let rec = fixture();
        let meta = SnapshotMeta {
            run: "fix-a".into(),
            shard: 2,
            shards: 4,
            ideal_wet_s: 1.0 / 3.0,
        };
        let text = to_jsonl(&meta, &rec);
        let (m2, r2) = from_jsonl(&text).unwrap();
        assert_eq!(m2, meta);
        // Debug formatting round-trips every f64 exactly, so string
        // equality here is bit-for-bit recorder equality.
        assert_eq!(format!("{rec:?}"), format!("{r2:?}"));
        assert_eq!(r2.ts.len(), rec.ts.len(), "zero tail buckets survive");
        // And a second trip is a fixed point.
        assert_eq!(to_jsonl(&m2, &r2), text);
    }

    #[test]
    fn empty_recorder_round_trips() {
        let meta = SnapshotMeta {
            run: "empty".into(),
            shard: 0,
            shards: 1,
            ideal_wet_s: 0.0,
        };
        let text = to_jsonl(&meta, &Recorder::new());
        let (m2, r2) = from_jsonl(&text).unwrap();
        assert_eq!(m2, meta);
        assert_eq!(r2.tasks_done(), 0);
        assert!(r2.ts.is_empty());
    }

    #[test]
    fn truncated_envelope_is_typed_error() {
        let meta = SnapshotMeta {
            run: "t".into(),
            shard: 0,
            shards: 2,
            ideal_wet_s: 1.0,
        };
        let text = to_jsonl(&meta, &fixture());
        // Drop the trailing `end` record.
        let cut = text.rsplit_once("{\"kind\":\"end\"").unwrap().0;
        match from_jsonl(cut) {
            Err(Error::Config(ConfigError::MissingKey { key, .. })) => assert_eq!(key, "end"),
            other => panic!("expected typed truncation error, got {other:?}"),
        }
        // Empty input is the same class of failure.
        assert!(matches!(
            from_jsonl(""),
            Err(Error::Config(ConfigError::MissingKey { .. }))
        ));
    }

    #[test]
    fn corrupt_lines_are_typed_errors() {
        let meta = SnapshotMeta {
            run: "c".into(),
            shard: 1,
            shards: 2,
            ideal_wet_s: 1.0,
        };
        let good = to_jsonl(&meta, &fixture());
        for mangle in [
            good.replacen("\"kind\":\"bucket\"", "\"kind\":\"bukket\"", 1),
            good.replacen("\"sec\":1", "\"sec\":9", 1),
            good.replacen("\"hits_local\"", "\"hits_lokal\"", 1),
            good.replacen("\"schema\":1", "\"schema\":9", 1),
            good.replacen("{\"kind\":\"bucket\"", "\"kind\":\"bucket\"", 1),
            format!("{good}garbage\n"),
        ] {
            match from_jsonl(&mangle) {
                Err(Error::Config(_)) => {}
                other => panic!("expected typed config error, got {other:?}"),
            }
        }
        // A bucket line silently deleted: the meta count catches it.
        let dropped: String = good
            .lines()
            .filter(|l| !l.contains("\"sec\":4"))
            .map(|l| format!("{l}\n"))
            .collect();
        assert!(matches!(
            from_jsonl(&dropped),
            Err(Error::Config(ConfigError::InvalidValue { .. }))
        ));
    }

    #[test]
    fn merge_of_parsed_shards_matches_in_process_absorb() {
        let a = fixture();
        let mut b = fixture();
        b.record_access(Micros::from_secs(9), AccessKind::Miss, 7);
        let mut direct = Recorder::new();
        direct.absorb(a.clone());
        direct.absorb(b.clone());

        let mut via_files = Recorder::new();
        for (i, r) in [a, b].into_iter().enumerate() {
            let meta = SnapshotMeta {
                run: "m".into(),
                shard: i,
                shards: 2,
                ideal_wet_s: 2.0,
            };
            let (_, parsed) = from_jsonl(&to_jsonl(&meta, &r)).unwrap();
            via_files.absorb(parsed);
        }
        assert_eq!(format!("{direct:?}"), format!("{via_files:?}"));
    }
}
