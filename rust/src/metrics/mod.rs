//! Metrics collection — the measured/computed quantities of §5.2.1:
//! ideal vs achieved throughput, node count, wait-queue length, CPU
//! utilization, cache hit-local/hit-global/miss rates, response times,
//! CPU time, and the derived efficiency/speedup/PI/slowdown statistics
//! of §5.2.4–§5.2.6.

use crate::coordinator::AccessKind;
use crate::util::stats::percentile;
use crate::util::time::Micros;
use crate::util::units::bps_to_gbps;

/// Per-second sample bucket (the summary-view time series of Figs 4–10).
#[derive(Debug, Clone, Copy, Default)]
pub struct Bucket {
    /// Bytes served from local caches this second.
    pub bytes_local: u64,
    /// Bytes served from peer caches this second.
    pub bytes_remote: u64,
    /// Bytes served from persistent storage (GPFS) this second.
    pub bytes_gpfs: u64,
    /// Tasks completed this second.
    pub tasks_completed: u32,
    /// Tasks that arrived this second.
    pub arrivals: u32,
    /// Wait-queue length at the end of the second.
    pub queue_len: u32,
    /// Registered nodes at the end of the second.
    pub nodes: u32,
    /// Busy CPU slots at the end of the second.
    pub busy_slots: u32,
    /// Total CPU slots at the end of the second.
    pub total_slots: u32,
}

impl Bucket {
    /// All bytes moved this second.
    pub fn bytes_total(&self) -> u64 {
        self.bytes_local + self.bytes_remote + self.bytes_gpfs
    }
}

/// The full per-second time series of one run.
#[derive(Debug, Default, Clone)]
pub struct TimeSeries {
    buckets: Vec<Bucket>,
}

impl TimeSeries {
    /// Empty series.
    pub fn new() -> Self {
        Self::default()
    }

    /// Mutable bucket for second `sec`, growing as needed.
    pub fn bucket_mut(&mut self, sec: u64) -> &mut Bucket {
        let i = sec as usize;
        if i >= self.buckets.len() {
            self.buckets.resize(i + 1, Bucket::default());
        }
        &mut self.buckets[i]
    }

    /// All buckets, second 0 onward.
    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// Length in seconds.
    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    /// True when no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// Measured aggregate throughput in Gb/s for second `sec`.
    pub fn throughput_gbps(&self, sec: usize) -> f64 {
        self.buckets
            .get(sec)
            .map_or(0.0, |b| bps_to_gbps(b.bytes_total() as f64))
    }

    /// Per-second total throughput series (Gb/s).
    pub fn throughput_series(&self) -> Vec<f64> {
        self.buckets
            .iter()
            .map(|b| bps_to_gbps(b.bytes_total() as f64))
            .collect()
    }
}

/// Per arrival-rate-interval statistics (slowdown, Fig 14).
#[derive(Debug, Clone, Default)]
pub struct IntervalStat {
    /// Arrival rate during this interval (tasks/s).
    pub rate: f64,
    /// Interval start (first arrival).
    pub start: Micros,
    /// Last *arrival* in this interval.
    pub last_arrival: Micros,
    /// Last *completion* of a task that arrived in this interval.
    pub last_completion: Micros,
    /// Tasks in this interval.
    pub tasks: u64,
}

impl IntervalStat {
    /// Slowdown = measured makespan of this interval's tasks over the
    /// ideal (tasks finish as they arrive).
    pub fn slowdown(&self) -> f64 {
        let ideal = (self.last_arrival - self.start).as_secs_f64();
        let actual = (self.last_completion.saturating_sub(self.start)).as_secs_f64();
        if ideal <= 0.0 {
            // Single-arrival interval: compare against a 1/rate quantum.
            let quantum = if self.rate > 0.0 { 1.0 / self.rate } else { 1.0 };
            return (actual / quantum).max(1.0);
        }
        (actual / ideal).max(1.0)
    }
}

/// Recorder driven by the engines during a run.
#[derive(Debug, Default)]
pub struct Recorder {
    /// Per-second series.
    pub ts: TimeSeries,
    hits_local: u64,
    hits_global: u64,
    misses: u64,
    resp_sum_s: f64,
    resp_max_s: f64,
    tasks_done: u64,
    last_completion: Micros,
    /// CPU time integral: slot-seconds of *registered* capacity (the
    /// paper's CPU-hours consumed, Fig 13).
    cpu_slot_seconds: f64,
    /// Per-interval slowdown accounting.
    pub intervals: Vec<IntervalStat>,
    queue_max: usize,
}

impl Recorder {
    /// Fresh recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one file access of `bytes` at time `now`.
    pub fn record_access(&mut self, now: Micros, kind: AccessKind, bytes: u64) {
        let b = self.ts.bucket_mut(now.as_secs());
        match kind {
            AccessKind::HitLocal => {
                self.hits_local += 1;
                b.bytes_local += bytes;
            }
            AccessKind::HitGlobal => {
                self.hits_global += 1;
                b.bytes_remote += bytes;
            }
            AccessKind::Miss => {
                self.misses += 1;
                b.bytes_gpfs += bytes;
            }
        }
    }

    /// Record a task arrival (and its interval for slowdown accounting).
    pub fn record_arrival(&mut self, now: Micros, interval: u32, rate: f64) {
        self.ts.bucket_mut(now.as_secs()).arrivals += 1;
        let i = interval as usize;
        if i >= self.intervals.len() {
            self.intervals.resize(i + 1, IntervalStat::default());
            self.intervals[i].start = now;
            self.intervals[i].rate = rate;
        }
        let stat = &mut self.intervals[i];
        stat.last_arrival = stat.last_arrival.max(now);
        stat.tasks += 1;
    }

    /// Record a task completion; `arrival` and `interval` come from the
    /// task, `now` is completion (result delivered).
    pub fn record_completion(&mut self, now: Micros, arrival: Micros, interval: u32) {
        self.ts.bucket_mut(now.as_secs()).tasks_completed += 1;
        let resp = (now - arrival).as_secs_f64();
        self.resp_sum_s += resp;
        self.resp_max_s = self.resp_max_s.max(resp);
        self.tasks_done += 1;
        self.last_completion = self.last_completion.max(now);
        if let Some(stat) = self.intervals.get_mut(interval as usize) {
            stat.last_completion = stat.last_completion.max(now);
        }
    }

    /// Periodic (1 Hz) cluster sample.
    pub fn sample(
        &mut self,
        now: Micros,
        queue_len: usize,
        nodes: usize,
        busy_slots: u64,
        total_slots: u64,
    ) {
        let b = self.ts.bucket_mut(now.as_secs());
        b.queue_len = queue_len.min(u32::MAX as usize) as u32;
        b.nodes = nodes as u32;
        b.busy_slots = busy_slots as u32;
        b.total_slots = total_slots as u32;
        self.cpu_slot_seconds += total_slots as f64;
        self.queue_max = self.queue_max.max(queue_len);
    }

    /// Tasks completed so far.
    pub fn tasks_done(&self) -> u64 {
        self.tasks_done
    }

    /// Raw access tallies `(hits_local, hits_global, misses)` — the §5.2.1
    /// three-way split as counts. Both engines' reports read this instead
    /// of keeping ad-hoc counters (the coordinator core owns the one
    /// recorder that sees every access).
    pub fn access_counts(&self) -> (u64, u64, u64) {
        (self.hits_local, self.hits_global, self.misses)
    }

    /// Finalize into summary metrics.
    pub fn summarize(&self, ideal_wet_s: f64) -> SummaryMetrics {
        let accesses = (self.hits_local + self.hits_global + self.misses).max(1);
        let wet = self.last_completion.as_secs_f64();
        let tp = self.ts.throughput_series();
        // Average over the active portion (ignore trailing zeros).
        let active: Vec<f64> = tp.iter().copied().filter(|&x| x > 0.0).collect();
        let cpu_time_h = self.cpu_slot_seconds / 3600.0;
        SummaryMetrics {
            workload_execution_time_s: wet,
            ideal_wet_s,
            efficiency: if wet > 0.0 { (ideal_wet_s / wet).min(1.0) } else { 0.0 },
            hit_local_rate: self.hits_local as f64 / accesses as f64,
            hit_global_rate: self.hits_global as f64 / accesses as f64,
            miss_rate: self.misses as f64 / accesses as f64,
            avg_throughput_gbps: crate::util::stats::mean(&active),
            peak_throughput_gbps: percentile(&tp, 0.99),
            avg_response_time_s: if self.tasks_done > 0 {
                self.resp_sum_s / self.tasks_done as f64
            } else {
                0.0
            },
            max_response_time_s: self.resp_max_s,
            cpu_time_hours: cpu_time_h,
            tasks_completed: self.tasks_done,
            queue_max_len: self.queue_max,
            avg_cpu_utilization: {
                let samples: Vec<&Bucket> = self
                    .ts
                    .buckets()
                    .iter()
                    .filter(|b| b.total_slots > 0)
                    .collect();
                if samples.is_empty() {
                    0.0
                } else {
                    samples
                        .iter()
                        .map(|b| b.busy_slots as f64 / b.total_slots as f64)
                        .sum::<f64>()
                        / samples.len() as f64
                }
            },
        }
    }
}

/// End-of-run summary (the numbers the paper reports per experiment).
#[derive(Debug, Clone, Default)]
pub struct SummaryMetrics {
    /// Workload execution time (s) — first arrival to last completion.
    pub workload_execution_time_s: f64,
    /// Ideal WET (s) from the arrival function.
    pub ideal_wet_s: f64,
    /// Efficiency = ideal / measured (§5.2: 28 %…99 %).
    pub efficiency: f64,
    /// HR_L — local cache-hit fraction.
    pub hit_local_rate: f64,
    /// HR_C — remote (peer cache) hit fraction.
    pub hit_global_rate: f64,
    /// HR_S — miss (persistent storage) fraction.
    pub miss_rate: f64,
    /// Mean aggregate throughput over active seconds, Gb/s.
    pub avg_throughput_gbps: f64,
    /// 99th-percentile per-second throughput, Gb/s (the paper's "peak").
    pub peak_throughput_gbps: f64,
    /// Mean end-to-end response time (s), §5.2.6.
    pub avg_response_time_s: f64,
    /// Worst response time (s).
    pub max_response_time_s: f64,
    /// CPU hours of registered capacity (Fig 13 PI denominator).
    pub cpu_time_hours: f64,
    /// Tasks completed.
    pub tasks_completed: u64,
    /// Peak wait-queue length.
    pub queue_max_len: usize,
    /// Mean CPU utilization over sampled seconds.
    pub avg_cpu_utilization: f64,
}

impl SummaryMetrics {
    /// Speedup of this run relative to a baseline WET (paper:
    /// `SP = WET_GPFS / WET_DD`).
    pub fn speedup_vs(&self, baseline_wet_s: f64) -> f64 {
        if self.workload_execution_time_s > 0.0 {
            baseline_wet_s / self.workload_execution_time_s
        } else {
            0.0
        }
    }

    /// Raw (unnormalized) performance index `PI = SP / CPU_T` (paper
    /// normalizes across experiments; see the report layer).
    pub fn performance_index_raw(&self, baseline_wet_s: f64) -> f64 {
        if self.cpu_time_hours > 0.0 {
            self.speedup_vs(baseline_wet_s) / self.cpu_time_hours
        } else {
            0.0
        }
    }

    /// Slowdown vs the ideal WET (`SL = WET_policy / WET_ideal`).
    pub fn slowdown(&self) -> f64 {
        if self.ideal_wet_s > 0.0 {
            self.workload_execution_time_s / self.ideal_wet_s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_accounting() {
        let mut r = Recorder::new();
        r.record_access(Micros::from_secs(1), AccessKind::HitLocal, 100);
        r.record_access(Micros::from_secs(1), AccessKind::Miss, 50);
        r.record_access(Micros::from_secs(2), AccessKind::HitGlobal, 25);
        let b1 = r.ts.buckets()[1];
        assert_eq!(b1.bytes_local, 100);
        assert_eq!(b1.bytes_gpfs, 50);
        assert_eq!(b1.bytes_total(), 150);
        assert_eq!(r.ts.buckets()[2].bytes_remote, 25);
    }

    #[test]
    fn summary_rates_sum_to_one() {
        let mut r = Recorder::new();
        for i in 0..60 {
            let kind = match i % 3 {
                0 => AccessKind::HitLocal,
                1 => AccessKind::HitGlobal,
                _ => AccessKind::Miss,
            };
            r.record_access(Micros::from_secs(i), kind, 1000);
        }
        let s = r.summarize(100.0);
        let total = s.hit_local_rate + s.hit_global_rate + s.miss_rate;
        assert!((total - 1.0).abs() < 1e-12);
        assert!((s.hit_local_rate - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn response_time_and_wet() {
        let mut r = Recorder::new();
        r.record_arrival(Micros::from_secs(0), 0, 1.0);
        r.record_arrival(Micros::from_secs(10), 0, 1.0);
        r.record_completion(Micros::from_secs(5), Micros::from_secs(0), 0);
        r.record_completion(Micros::from_secs(30), Micros::from_secs(10), 0);
        let s = r.summarize(30.0);
        assert_eq!(s.workload_execution_time_s, 30.0);
        assert_eq!(s.avg_response_time_s, 12.5);
        assert_eq!(s.max_response_time_s, 20.0);
        assert_eq!(s.efficiency, 1.0);
        assert_eq!(s.tasks_completed, 2);
    }

    #[test]
    fn cpu_time_integrates_capacity() {
        let mut r = Recorder::new();
        for sec in 0..3600 {
            r.sample(Micros::from_secs(sec), 0, 64, 0, 128);
        }
        let s = r.summarize(1.0);
        assert!((s.cpu_time_hours - 128.0).abs() < 1e-9);
    }

    #[test]
    fn interval_slowdown() {
        let mut stat = IntervalStat {
            rate: 10.0,
            start: Micros::from_secs(0),
            last_arrival: Micros::from_secs(60),
            last_completion: Micros::from_secs(120),
            tasks: 600,
        };
        assert!((stat.slowdown() - 2.0).abs() < 1e-9);
        stat.last_completion = Micros::from_secs(30);
        assert_eq!(stat.slowdown(), 1.0, "slowdown floors at 1");
    }

    #[test]
    fn speedup_and_pi() {
        let s = SummaryMetrics {
            workload_execution_time_s: 1436.0,
            cpu_time_hours: 24.0,
            ..SummaryMetrics::default()
        };
        let sp = s.speedup_vs(5011.0);
        assert!((sp - 3.49).abs() < 0.01);
        assert!((s.performance_index_raw(5011.0) - sp / 24.0).abs() < 1e-12);
    }

    #[test]
    fn queue_high_water() {
        let mut r = Recorder::new();
        r.sample(Micros::from_secs(0), 10, 1, 0, 2);
        r.sample(Micros::from_secs(1), 500, 1, 0, 2);
        r.sample(Micros::from_secs(2), 3, 1, 0, 2);
        assert_eq!(r.summarize(1.0).queue_max_len, 500);
    }
}
