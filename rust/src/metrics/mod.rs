//! Metrics collection — the measured/computed quantities of §5.2.1:
//! ideal vs achieved throughput, node count, wait-queue length, CPU
//! utilization, cache hit-local/hit-global/miss rates, response times,
//! CPU time, and the derived efficiency/speedup/PI/slowdown statistics
//! of §5.2.4–§5.2.6.
//!
//! Sharded runs add two pieces (PR 5):
//!
//! * [`ShardCounters`] / [`ShardTally`] — router-level tallies (events
//!   fanned in, cross-shard fetch rewrites, per-shard routing and
//!   transfer accounting) kept by
//!   [`crate::coordinator::shard::ShardedCoordinator`];
//! * [`Recorder::absorb`] / [`TimeSeries::absorb`] — lossless merging of
//!   per-shard recorders into one cluster view, so a K-shard run reports
//!   through the same [`SummaryMetrics`] pipeline as a single core.

use crate::coordinator::AccessKind;
use crate::util::stats::percentile;
use crate::util::time::Micros;
use crate::util::units::bps_to_gbps;

pub mod snapshot;

/// Per-second sample bucket (the summary-view time series of Figs 4–10).
#[derive(Debug, Clone, Copy, Default)]
pub struct Bucket {
    /// Bytes served from local caches this second.
    pub bytes_local: u64,
    /// Bytes served from peer caches this second.
    pub bytes_remote: u64,
    /// Bytes served from persistent storage (GPFS) this second.
    pub bytes_gpfs: u64,
    /// Tasks completed this second.
    pub tasks_completed: u32,
    /// Tasks that arrived this second.
    pub arrivals: u32,
    /// Wait-queue length at the end of the second.
    pub queue_len: u32,
    /// Registered nodes at the end of the second.
    pub nodes: u32,
    /// Busy CPU slots at the end of the second.
    pub busy_slots: u32,
    /// Total CPU slots at the end of the second.
    pub total_slots: u32,
}

impl Bucket {
    /// All bytes moved this second.
    pub fn bytes_total(&self) -> u64 {
        self.bytes_local + self.bytes_remote + self.bytes_gpfs
    }
}

/// The full per-second time series of one run.
#[derive(Debug, Default, Clone)]
pub struct TimeSeries {
    buckets: Vec<Bucket>,
}

impl TimeSeries {
    /// Empty series.
    pub fn new() -> Self {
        Self::default()
    }

    /// Mutable bucket for second `sec`, growing as needed.
    pub fn bucket_mut(&mut self, sec: u64) -> &mut Bucket {
        let i = sec as usize;
        if i >= self.buckets.len() {
            self.buckets.resize(i + 1, Bucket::default());
        }
        &mut self.buckets[i]
    }

    /// All buckets, second 0 onward.
    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// Length in seconds.
    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    /// True when no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// Measured aggregate throughput in Gb/s for second `sec`.
    pub fn throughput_gbps(&self, sec: usize) -> f64 {
        self.buckets
            .get(sec)
            .map_or(0.0, |b| bps_to_gbps(b.bytes_total() as f64))
    }

    /// Per-second total throughput series (Gb/s).
    pub fn throughput_series(&self) -> Vec<f64> {
        self.buckets
            .iter()
            .map(|b| bps_to_gbps(b.bytes_total() as f64))
            .collect()
    }

    /// Merge another series (a shard's) into this one, element-wise.
    /// Every bucket field adds: byte and task counts are naturally
    /// additive, and the queue/node/slot gauges are sampled at the same
    /// 1 Hz instants by every shard's `on_tick`, so their per-second sums
    /// are the cluster-wide gauge values.
    pub fn absorb(&mut self, other: TimeSeries) {
        if self.buckets.len() < other.buckets.len() {
            self.buckets.resize(other.buckets.len(), Bucket::default());
        }
        for (b, o) in self.buckets.iter_mut().zip(other.buckets) {
            b.bytes_local += o.bytes_local;
            b.bytes_remote += o.bytes_remote;
            b.bytes_gpfs += o.bytes_gpfs;
            b.tasks_completed += o.tasks_completed;
            b.arrivals += o.arrivals;
            b.queue_len += o.queue_len;
            b.nodes += o.nodes;
            b.busy_slots += o.busy_slots;
            b.total_slots += o.total_slots;
        }
    }
}

/// Per arrival-rate-interval statistics (slowdown, Fig 14).
#[derive(Debug, Clone, Default)]
pub struct IntervalStat {
    /// Arrival rate during this interval (tasks/s).
    pub rate: f64,
    /// Interval start (first arrival).
    pub start: Micros,
    /// Last *arrival* in this interval.
    pub last_arrival: Micros,
    /// Last *completion* of a task that arrived in this interval.
    pub last_completion: Micros,
    /// Tasks in this interval.
    pub tasks: u64,
}

impl IntervalStat {
    /// Slowdown = measured makespan of this interval's tasks over the
    /// ideal (tasks finish as they arrive).
    pub fn slowdown(&self) -> f64 {
        let ideal = (self.last_arrival - self.start).as_secs_f64();
        let actual = (self.last_completion.saturating_sub(self.start)).as_secs_f64();
        if ideal <= 0.0 {
            // Single-arrival interval: compare against a 1/rate quantum.
            let quantum = if self.rate > 0.0 { 1.0 / self.rate } else { 1.0 };
            return (actual / quantum).max(1.0);
        }
        (actual / ideal).max(1.0)
    }

    /// Merge another shard's view of the *same* arrival interval: the
    /// interval's tasks were split across shards, so counts add and the
    /// time bounds widen (earliest start, latest arrival/completion).
    pub fn absorb(&mut self, other: &IntervalStat) {
        if other.tasks == 0 {
            return;
        }
        if self.tasks == 0 {
            *self = other.clone();
            return;
        }
        self.start = self.start.min(other.start);
        self.last_arrival = self.last_arrival.max(other.last_arrival);
        self.last_completion = self.last_completion.max(other.last_completion);
        self.tasks += other.tasks;
        // `rate` is the workload stage's arrival rate — identical in
        // every shard's copy by construction; keep ours.
    }
}

/// Per-shard routing/transfer tallies (one entry per shard in
/// [`ShardCounters::per_shard`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ShardTally {
    /// Tasks the router assigned to this shard (dominant-file hash).
    pub tasks_routed: u64,
    /// Tasks this shard's core dispatched (filled at end of run).
    pub dispatches: u64,
    /// Cross-shard fetches *into* this shard (it was the destination:
    /// one of its executors pulled a file cached on a foreign shard).
    pub cross_in: u64,
    /// Cross-shard fetches *out of* this shard (one of its executors
    /// served a foreign shard's fetch from its cache).
    pub cross_out: u64,
}

/// Router-level tallies of a sharded run — the cross-shard accounting
/// the ROADMAP's "multi-coordinator sharding" item calls for. Kept by
/// [`crate::coordinator::shard::ShardedCoordinator`]; surfaced in
/// [`crate::sim::RunResult`], printed by `datadiff run --shards K`, and
/// snapshotted as the `shard/*` counters `tools/bench_gate.py` gates.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ShardCounters {
    /// Number of coordinator shards (1 = plain single core).
    pub shards: usize,
    /// Driver events fanned through the router (arrivals, pickups,
    /// fetch/compute completions, ticks, kicks, registrations).
    pub router_events: u64,
    /// GPFS misses the router rewrote into cross-shard peer fetches.
    pub cross_fetches: u64,
    /// Bytes moved by those cross-shard fetches.
    pub cross_bytes: u64,
    /// Release decisions the router withheld because the executor was
    /// serving a cross-shard peer transfer (its own shard cannot see
    /// that serving window — the plan lives on the destination shard).
    pub cross_release_deferrals: u64,
    /// Executor crash events the router fanned into
    /// `on_executor_failed` (chaos harness / live worker deaths).
    pub exec_failures: u64,
    /// Events the router rejected because they named a task it never
    /// saw arrive (or one already completed) — byzantine duplicates and
    /// corrupted completions bounce off here without reaching a core.
    pub stale_events: u64,
    /// Per-shard breakdown, indexed by shard id.
    pub per_shard: Vec<ShardTally>,
}

impl ShardCounters {
    /// Fresh counters for a `shards`-way router.
    pub fn new(shards: usize) -> Self {
        ShardCounters {
            shards,
            per_shard: vec![ShardTally::default(); shards],
            ..ShardCounters::default()
        }
    }

    /// Tasks routed across all shards.
    pub fn tasks_routed(&self) -> u64 {
        self.per_shard.iter().map(|t| t.tasks_routed).sum()
    }

    /// Cross-shard fetches per routed task. A task can cross at most
    /// once per *file* (each file is fetched once), so on workloads
    /// where tasks have at most one foreign-homed secondary file — the
    /// paper's single-file streams, and the `perf_hotpath`/`shard_parity`
    /// pair-task fixtures — the ratio is bounded by 1.0 and the CI gate
    /// enforces that; a breach there means the router double-accounted.
    /// A workload of tasks with several foreign-homed files can
    /// legitimately exceed 1.0.
    pub fn cross_fetches_per_task(&self) -> f64 {
        self.cross_fetches as f64 / self.tasks_routed().max(1) as f64
    }
}

/// Recorder driven by the engines during a run.
#[derive(Debug, Default, Clone)]
pub struct Recorder {
    /// Per-second series.
    pub ts: TimeSeries,
    hits_local: u64,
    hits_global: u64,
    misses: u64,
    resp_sum_s: f64,
    resp_max_s: f64,
    tasks_done: u64,
    last_completion: Micros,
    /// CPU time integral: slot-seconds of *registered* capacity (the
    /// paper's CPU-hours consumed, Fig 13).
    cpu_slot_seconds: f64,
    /// Per-interval slowdown accounting.
    pub intervals: Vec<IntervalStat>,
    queue_max: usize,
}

impl Recorder {
    /// Fresh recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one file access of `bytes` at time `now`.
    pub fn record_access(&mut self, now: Micros, kind: AccessKind, bytes: u64) {
        let b = self.ts.bucket_mut(now.as_secs());
        match kind {
            AccessKind::HitLocal => {
                self.hits_local += 1;
                b.bytes_local += bytes;
            }
            AccessKind::HitGlobal => {
                self.hits_global += 1;
                b.bytes_remote += bytes;
            }
            AccessKind::Miss => {
                self.misses += 1;
                b.bytes_gpfs += bytes;
            }
        }
    }

    /// Record a task arrival (and its interval for slowdown accounting).
    pub fn record_arrival(&mut self, now: Micros, interval: u32, rate: f64) {
        self.ts.bucket_mut(now.as_secs()).arrivals += 1;
        let i = interval as usize;
        if i >= self.intervals.len() {
            self.intervals.resize(i + 1, IntervalStat::default());
            self.intervals[i].start = now;
            self.intervals[i].rate = rate;
        }
        let stat = &mut self.intervals[i];
        stat.last_arrival = stat.last_arrival.max(now);
        stat.tasks += 1;
    }

    /// Record a task completion; `arrival` and `interval` come from the
    /// task, `now` is completion (result delivered).
    pub fn record_completion(&mut self, now: Micros, arrival: Micros, interval: u32) {
        self.ts.bucket_mut(now.as_secs()).tasks_completed += 1;
        let resp = (now - arrival).as_secs_f64();
        self.resp_sum_s += resp;
        self.resp_max_s = self.resp_max_s.max(resp);
        self.tasks_done += 1;
        self.last_completion = self.last_completion.max(now);
        if let Some(stat) = self.intervals.get_mut(interval as usize) {
            stat.last_completion = stat.last_completion.max(now);
        }
    }

    /// Periodic (1 Hz) cluster sample.
    pub fn sample(
        &mut self,
        now: Micros,
        queue_len: usize,
        nodes: usize,
        busy_slots: u64,
        total_slots: u64,
    ) {
        let b = self.ts.bucket_mut(now.as_secs());
        b.queue_len = queue_len.min(u32::MAX as usize) as u32;
        b.nodes = nodes as u32;
        b.busy_slots = busy_slots as u32;
        b.total_slots = total_slots as u32;
        self.cpu_slot_seconds += total_slots as f64;
        self.queue_max = self.queue_max.max(queue_len);
    }

    /// Tasks completed so far.
    pub fn tasks_done(&self) -> u64 {
        self.tasks_done
    }

    /// Merge another recorder (one shard's) into this one, losslessly:
    /// counts and integrals add, extrema take the max, the time series
    /// merges element-wise, and same-index arrival intervals combine via
    /// [`IntervalStat::absorb`]. After the buckets are summed the queue
    /// high-water mark is re-derived from the merged series, so it
    /// reflects the *cluster-wide* peak backlog (per-shard peaks alone
    /// would under-report it). Absorbing one recorder into a fresh one
    /// reproduces it exactly — the K=1 case of the shard router's
    /// end-of-run merge.
    pub fn absorb(&mut self, other: Recorder) {
        self.ts.absorb(other.ts);
        self.hits_local += other.hits_local;
        self.hits_global += other.hits_global;
        self.misses += other.misses;
        self.resp_sum_s += other.resp_sum_s;
        self.resp_max_s = self.resp_max_s.max(other.resp_max_s);
        self.tasks_done += other.tasks_done;
        self.last_completion = self.last_completion.max(other.last_completion);
        self.cpu_slot_seconds += other.cpu_slot_seconds;
        if self.intervals.len() < other.intervals.len() {
            self.intervals
                .resize(other.intervals.len(), IntervalStat::default());
        }
        for (mine, theirs) in self.intervals.iter_mut().zip(&other.intervals) {
            mine.absorb(theirs);
        }
        let series_peak = self
            .ts
            .buckets()
            .iter()
            .map(|b| b.queue_len as usize)
            .max()
            .unwrap_or(0);
        self.queue_max = self.queue_max.max(other.queue_max).max(series_peak);
    }

    /// Raw access tallies `(hits_local, hits_global, misses)` — the §5.2.1
    /// three-way split as counts. Both engines' reports read this instead
    /// of keeping ad-hoc counters (the coordinator core owns the one
    /// recorder that sees every access).
    pub fn access_counts(&self) -> (u64, u64, u64) {
        (self.hits_local, self.hits_global, self.misses)
    }

    /// Finalize into summary metrics.
    pub fn summarize(&self, ideal_wet_s: f64) -> SummaryMetrics {
        let accesses = (self.hits_local + self.hits_global + self.misses).max(1);
        let wet = self.last_completion.as_secs_f64();
        let tp = self.ts.throughput_series();
        // Average over the active portion (ignore trailing zeros).
        let active: Vec<f64> = tp.iter().copied().filter(|&x| x > 0.0).collect();
        let cpu_time_h = self.cpu_slot_seconds / 3600.0;
        SummaryMetrics {
            workload_execution_time_s: wet,
            ideal_wet_s,
            efficiency: if wet > 0.0 { (ideal_wet_s / wet).min(1.0) } else { 0.0 },
            hit_local_rate: self.hits_local as f64 / accesses as f64,
            hit_global_rate: self.hits_global as f64 / accesses as f64,
            miss_rate: self.misses as f64 / accesses as f64,
            avg_throughput_gbps: crate::util::stats::mean(&active),
            peak_throughput_gbps: percentile(&tp, 0.99),
            avg_response_time_s: if self.tasks_done > 0 {
                self.resp_sum_s / self.tasks_done as f64
            } else {
                0.0
            },
            max_response_time_s: self.resp_max_s,
            cpu_time_hours: cpu_time_h,
            tasks_completed: self.tasks_done,
            queue_max_len: self.queue_max,
            avg_cpu_utilization: {
                let samples: Vec<&Bucket> = self
                    .ts
                    .buckets()
                    .iter()
                    .filter(|b| b.total_slots > 0)
                    .collect();
                if samples.is_empty() {
                    0.0
                } else {
                    samples
                        .iter()
                        .map(|b| b.busy_slots as f64 / b.total_slots as f64)
                        .sum::<f64>()
                        / samples.len() as f64
                }
            },
        }
    }
}

/// End-of-run summary (the numbers the paper reports per experiment).
#[derive(Debug, Clone, Default)]
pub struct SummaryMetrics {
    /// Workload execution time (s) — first arrival to last completion.
    pub workload_execution_time_s: f64,
    /// Ideal WET (s) from the arrival function.
    pub ideal_wet_s: f64,
    /// Efficiency = ideal / measured (§5.2: 28 %…99 %).
    pub efficiency: f64,
    /// HR_L — local cache-hit fraction.
    pub hit_local_rate: f64,
    /// HR_C — remote (peer cache) hit fraction.
    pub hit_global_rate: f64,
    /// HR_S — miss (persistent storage) fraction.
    pub miss_rate: f64,
    /// Mean aggregate throughput over active seconds, Gb/s.
    pub avg_throughput_gbps: f64,
    /// 99th-percentile per-second throughput, Gb/s (the paper's "peak").
    pub peak_throughput_gbps: f64,
    /// Mean end-to-end response time (s), §5.2.6.
    pub avg_response_time_s: f64,
    /// Worst response time (s).
    pub max_response_time_s: f64,
    /// CPU hours of registered capacity (Fig 13 PI denominator).
    pub cpu_time_hours: f64,
    /// Tasks completed.
    pub tasks_completed: u64,
    /// Peak wait-queue length.
    pub queue_max_len: usize,
    /// Mean CPU utilization over sampled seconds.
    pub avg_cpu_utilization: f64,
}

impl SummaryMetrics {
    /// Speedup of this run relative to a baseline WET (paper:
    /// `SP = WET_GPFS / WET_DD`).
    pub fn speedup_vs(&self, baseline_wet_s: f64) -> f64 {
        if self.workload_execution_time_s > 0.0 {
            baseline_wet_s / self.workload_execution_time_s
        } else {
            0.0
        }
    }

    /// Raw (unnormalized) performance index `PI = SP / CPU_T` (paper
    /// normalizes across experiments; see the report layer).
    pub fn performance_index_raw(&self, baseline_wet_s: f64) -> f64 {
        if self.cpu_time_hours > 0.0 {
            self.speedup_vs(baseline_wet_s) / self.cpu_time_hours
        } else {
            0.0
        }
    }

    /// Slowdown vs the ideal WET (`SL = WET_policy / WET_ideal`).
    pub fn slowdown(&self) -> f64 {
        if self.ideal_wet_s > 0.0 {
            self.workload_execution_time_s / self.ideal_wet_s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_accounting() {
        let mut r = Recorder::new();
        r.record_access(Micros::from_secs(1), AccessKind::HitLocal, 100);
        r.record_access(Micros::from_secs(1), AccessKind::Miss, 50);
        r.record_access(Micros::from_secs(2), AccessKind::HitGlobal, 25);
        let b1 = r.ts.buckets()[1];
        assert_eq!(b1.bytes_local, 100);
        assert_eq!(b1.bytes_gpfs, 50);
        assert_eq!(b1.bytes_total(), 150);
        assert_eq!(r.ts.buckets()[2].bytes_remote, 25);
    }

    #[test]
    fn summary_rates_sum_to_one() {
        let mut r = Recorder::new();
        for i in 0..60 {
            let kind = match i % 3 {
                0 => AccessKind::HitLocal,
                1 => AccessKind::HitGlobal,
                _ => AccessKind::Miss,
            };
            r.record_access(Micros::from_secs(i), kind, 1000);
        }
        let s = r.summarize(100.0);
        let total = s.hit_local_rate + s.hit_global_rate + s.miss_rate;
        assert!((total - 1.0).abs() < 1e-12);
        assert!((s.hit_local_rate - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn response_time_and_wet() {
        let mut r = Recorder::new();
        r.record_arrival(Micros::from_secs(0), 0, 1.0);
        r.record_arrival(Micros::from_secs(10), 0, 1.0);
        r.record_completion(Micros::from_secs(5), Micros::from_secs(0), 0);
        r.record_completion(Micros::from_secs(30), Micros::from_secs(10), 0);
        let s = r.summarize(30.0);
        assert_eq!(s.workload_execution_time_s, 30.0);
        assert_eq!(s.avg_response_time_s, 12.5);
        assert_eq!(s.max_response_time_s, 20.0);
        assert_eq!(s.efficiency, 1.0);
        assert_eq!(s.tasks_completed, 2);
    }

    #[test]
    fn cpu_time_integrates_capacity() {
        let mut r = Recorder::new();
        for sec in 0..3600 {
            r.sample(Micros::from_secs(sec), 0, 64, 0, 128);
        }
        let s = r.summarize(1.0);
        assert!((s.cpu_time_hours - 128.0).abs() < 1e-9);
    }

    #[test]
    fn interval_slowdown() {
        let mut stat = IntervalStat {
            rate: 10.0,
            start: Micros::from_secs(0),
            last_arrival: Micros::from_secs(60),
            last_completion: Micros::from_secs(120),
            tasks: 600,
        };
        assert!((stat.slowdown() - 2.0).abs() < 1e-9);
        stat.last_completion = Micros::from_secs(30);
        assert_eq!(stat.slowdown(), 1.0, "slowdown floors at 1");
    }

    #[test]
    fn speedup_and_pi() {
        let s = SummaryMetrics {
            workload_execution_time_s: 1436.0,
            cpu_time_hours: 24.0,
            ..SummaryMetrics::default()
        };
        let sp = s.speedup_vs(5011.0);
        assert!((sp - 3.49).abs() < 0.01);
        assert!((s.performance_index_raw(5011.0) - sp / 24.0).abs() < 1e-12);
    }

    #[test]
    fn absorb_into_fresh_recorder_is_lossless() {
        let mut r = Recorder::new();
        r.record_arrival(Micros::from_secs(0), 0, 2.0);
        r.record_access(Micros::from_secs(1), AccessKind::HitLocal, 100);
        r.record_access(Micros::from_secs(2), AccessKind::Miss, 50);
        r.record_completion(Micros::from_secs(3), Micros::from_secs(0), 0);
        r.sample(Micros::from_secs(1), 7, 2, 1, 4);
        let reference = r.summarize(10.0);

        let mut merged = Recorder::new();
        merged.absorb(r);
        let got = merged.summarize(10.0);
        assert_eq!(got.tasks_completed, reference.tasks_completed);
        assert_eq!(got.hit_local_rate, reference.hit_local_rate);
        assert_eq!(got.miss_rate, reference.miss_rate);
        assert_eq!(got.avg_response_time_s, reference.avg_response_time_s);
        assert_eq!(got.cpu_time_hours, reference.cpu_time_hours);
        assert_eq!(got.queue_max_len, reference.queue_max_len);
        assert_eq!(
            got.workload_execution_time_s,
            reference.workload_execution_time_s
        );
        assert_eq!(merged.access_counts(), (1, 0, 1));
    }

    #[test]
    fn absorb_sums_shard_views() {
        // Two shards sampled at the same 1 Hz instants: gauges sum, the
        // cluster queue peak is derived from the merged series.
        let mut a = Recorder::new();
        a.sample(Micros::from_secs(0), 10, 1, 1, 2);
        a.sample(Micros::from_secs(1), 3, 1, 0, 2);
        a.record_access(Micros::from_secs(0), AccessKind::HitLocal, 100);
        let mut b = Recorder::new();
        b.sample(Micros::from_secs(0), 4, 1, 2, 2);
        b.sample(Micros::from_secs(1), 9, 1, 1, 2);
        b.record_access(Micros::from_secs(1), AccessKind::HitGlobal, 40);
        a.absorb(b);
        let buckets = a.ts.buckets();
        assert_eq!(buckets[0].queue_len, 14);
        assert_eq!(buckets[1].queue_len, 12);
        assert_eq!(buckets[0].nodes, 2);
        assert_eq!(buckets[0].busy_slots, 3);
        assert_eq!(buckets[0].total_slots, 4);
        assert_eq!(a.access_counts(), (1, 1, 0));
        // Neither shard alone peaked at 14; the merged series does.
        assert_eq!(a.summarize(1.0).queue_max_len, 14);
    }

    #[test]
    fn interval_absorb_widens_bounds_and_sums_tasks() {
        let mut a = IntervalStat {
            rate: 10.0,
            start: Micros::from_secs(5),
            last_arrival: Micros::from_secs(20),
            last_completion: Micros::from_secs(30),
            tasks: 100,
        };
        let b = IntervalStat {
            rate: 10.0,
            start: Micros::from_secs(4),
            last_arrival: Micros::from_secs(25),
            last_completion: Micros::from_secs(28),
            tasks: 50,
        };
        a.absorb(&b);
        assert_eq!(a.start, Micros::from_secs(4));
        assert_eq!(a.last_arrival, Micros::from_secs(25));
        assert_eq!(a.last_completion, Micros::from_secs(30));
        assert_eq!(a.tasks, 150);
        // Empty side is a no-op in either direction.
        let mut empty = IntervalStat::default();
        empty.absorb(&a);
        assert_eq!(empty.tasks, 150);
        a.absorb(&IntervalStat::default());
        assert_eq!(a.tasks, 150);
    }

    #[test]
    fn shard_counters_ratio() {
        let mut c = ShardCounters::new(4);
        assert_eq!(c.per_shard.len(), 4);
        c.per_shard[0].tasks_routed = 60;
        c.per_shard[3].tasks_routed = 40;
        c.cross_fetches = 25;
        assert_eq!(c.tasks_routed(), 100);
        assert!((c.cross_fetches_per_task() - 0.25).abs() < 1e-12);
        // Zero tasks must not divide by zero.
        assert_eq!(ShardCounters::new(2).cross_fetches_per_task(), 0.0);
    }

    #[test]
    fn queue_high_water() {
        let mut r = Recorder::new();
        r.sample(Micros::from_secs(0), 10, 1, 0, 2);
        r.sample(Micros::from_secs(1), 500, 1, 0, 2);
        r.sample(Micros::from_secs(2), 3, 1, 0, 2);
        assert_eq!(r.summarize(1.0).queue_max_len, 500);
    }
}
