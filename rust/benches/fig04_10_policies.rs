//! Figures 4–10 bench: the seven summary-view experiments at paper scale
//! (250K tasks, 64 nodes, 10K × 10 MB files).
//!
//!     cargo bench --bench fig04_10_policies
//!
//! Env: `DD_SCALE` scales the task count (default 1.0 = paper scale),
//! `DD_VIEW` sets the time-series sampling stride in seconds.

use datadiffusion::experiments::{self, fig04_10};

fn main() {
    datadiffusion::util::logger::init();
    let scale: f64 = std::env::var("DD_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let view: usize = std::env::var("DD_VIEW")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    let t0 = std::time::Instant::now();
    let results = fig04_10::scaled_run(scale);
    for t in fig04_10::tables(&results, view) {
        t.print();
    }
    let summary = experiments::summary_table(&results);
    let _ = summary.write_csv("fig04_10_summary");
    for r in &results {
        let _ = experiments::summary_view_table(r, 1).write_csv(&format!("{}_series", r.name));
    }
    let total_events: u64 = results.iter().map(|r| r.events_processed).sum();
    let total_wall: f64 = results.iter().map(|r| r.sim_wall_s).sum();
    println!(
        "\nfig04-10 done in {:.1}s ({} events, {:.2}M events/s simulated)",
        t0.elapsed().as_secs_f64(),
        total_events,
        total_events as f64 / total_wall / 1e6
    );
}
