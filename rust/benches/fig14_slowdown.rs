//! Figure 14 bench: slowdown vs arrival rate (§5.2.5 — paper:
//! first-available saturates at 59 tasks/s; big caches stay near 1×;
//! 1.5 GB recovers from ~5× to ~1× once the working set caches).
//!
//!     cargo bench --bench fig14_slowdown
//! Env: `DD_SCALE` (default 1.0).

use datadiffusion::experiments::{fig04_10, fig14};

fn main() {
    datadiffusion::util::logger::init();
    let scale: f64 = std::env::var("DD_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let results = fig04_10::scaled_run(scale);
    let t = fig14::table(&results);
    t.print();
    let _ = t.write_csv("fig14");

    for r in &results {
        if let Some(rate) = fig14::saturation_rate(r, 1.5) {
            println!("{}: saturates at ~{rate:.0} tasks/s", r.name);
        } else {
            println!("{}: never saturates (≤1.5× slowdown throughout)", r.name);
        }
    }
    println!("(paper: first-available saturates at 59 tasks/s)");
}
