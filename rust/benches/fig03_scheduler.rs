//! Figure 3 bench: raw data-aware scheduler throughput (§5.1 — paper:
//! 2981 decisions/s first-available → 1322/s max-cache-hit on a 2007
//! Xeon; our Rust implementation targets ≥10× that, see DESIGN.md §Perf).
//!
//!     cargo bench --bench fig03_scheduler
//!
//! Env: `DD_TASKS` (default 250000), `DD_NODES` (default 32).

use datadiffusion::experiments::fig03;

fn main() {
    datadiffusion::util::logger::init();
    let tasks: u64 = std::env::var("DD_TASKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(250_000);
    let nodes: usize = std::env::var("DD_NODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);
    println!(
        "scheduler microbenchmark: {tasks} tasks, 10K 1-byte files, {nodes} nodes, window {}",
        100 * nodes
    );
    let results = fig03::run(tasks, 10_000, nodes);
    let t = fig03::table(&results);
    t.print();
    let _ = t.write_csv("fig03_scheduler");

    // Shape check vs the paper: first-available is the fastest policy;
    // the data-aware policies cost more per decision.
    let fa = results
        .iter()
        .find(|r| r.policy.name() == "first-available")
        .expect("fa present");
    let mch = results
        .iter()
        .find(|r| r.policy.name() == "max-cache-hit")
        .expect("mch present");
    println!(
        "\nshape: first-available {:.0}/s vs max-cache-hit {:.0}/s ({:.1}× — paper 2.3×)",
        fa.decisions_per_sec,
        mch.decisions_per_sec,
        fa.decisions_per_sec / mch.decisions_per_sec
    );
}
