//! §Perf hot-path microbenchmarks (DESIGN.md §8, EXPERIMENTS.md §Perf).
//!
//! Covers the L3 hot paths: scheduler decisions (indexed pickup vs the
//! retained reference window scan), epoch-lazy pending-index maintenance
//! vs the eager reference under hot-file churn, memoized notify ranking,
//! wait-queue window ops, cache churn, flow-network transfer churn
//! (batched vs per-event reference rerating), the 4-shard coordinator
//! router (cross-shard fetch rewrites — `shard/*` counters), the seeded
//! chaos harness with its shadow oracle (`chaos/*` counters), the
//! workload scenario library generators (`workload/*` counters), the
//! model-predictive provisioning controller (`model/*` counters), the
//! million-task arena/SoA scale drive (`scale/*` counters;
//! `DATADIFF_SCALE_QUICK=1` shrinks it to 100K × 128 for CI smoke),
//! plus the whole-simulation event rate. Run before/after every
//! optimization:
//!
//!     cargo bench --bench perf_hotpath
//!
//! Results also land as JSON under `target/bench-results/perf_hotpath.json`;
//! with `DATADIFF_BENCH_BASELINE=1` the snapshot is written to
//! `BENCH_baseline.json` at the workspace root (the committed perf
//! trajectory — see that file's header). Besides wall times, the snapshot
//! carries **deterministic work counters** (tasks inspected per pickup,
//! boundary-cursor steps, flow rerates per event, pending maintenance ops
//! lazy-vs-eager, notify memo hits and holder recounts);
//! `tools/bench_gate.py` gates CI on those and on within-run ratios,
//! which shared-runner noise cannot fake. README "Benchmarks & CI gates"
//! documents every counter and its enforced ratio.

use datadiffusion::cache::{CacheConfig, EvictionPolicy, ObjectCache};
use datadiffusion::config::ExperimentConfig;
use datadiffusion::coordinator::core::{CoordinatorCore, CoreConfig, Effect, FileSizes};
use datadiffusion::coordinator::executor::ExecutorRegistry;
use datadiffusion::coordinator::pending::{remove_queued, PendingIndex, PendingStats};
use datadiffusion::coordinator::provisioner::{AllocationPolicy, ProvisionerConfig};
use datadiffusion::coordinator::queue::{Task, WaitQueue};
use datadiffusion::coordinator::scheduler::{DispatchPolicy, Scheduler, SchedulerConfig};
use datadiffusion::coordinator::shard::ShardedCoordinator;
use datadiffusion::ids::{ExecutorId, FileId, TaskId};
use datadiffusion::index::LocationIndex;
use datadiffusion::sim::flow::{FlowNet, RerateMode};
use datadiffusion::util::bench::{baseline_json, black_box, Bench};
use datadiffusion::util::prng::Pcg64;
use datadiffusion::util::time::Micros;

fn main() {
    datadiffusion::util::logger::init();
    let mut counters: Vec<(String, f64)> = Vec::new();
    let groups = vec![
        bench_scheduler_decision(&mut counters),
        bench_scheduler_reference_scan(),
        bench_pending_maintenance(&mut counters),
        bench_notify(&mut counters),
        bench_waitqueue(&mut counters),
        bench_cache(),
        bench_flownet(&mut counters),
        bench_sharded_router(&mut counters),
        bench_live(&mut counters),
        bench_chaos(&mut counters),
        bench_scenario_generation(&mut counters),
        bench_model_controller(&mut counters),
        bench_whole_sim(),
        bench_scale(&mut counters),
    ];
    println!("\n== counters (deterministic work metrics) ==");
    for (k, v) in &counters {
        println!("  {k:<52} {v:.4}");
    }
    let refs: Vec<&Bench> = groups.iter().collect();
    let json = baseline_json("perf_hotpath", &refs, &counters);
    let out = std::path::Path::new("target/bench-results");
    let _ = std::fs::create_dir_all(out);
    let _ = std::fs::write(out.join("perf_hotpath.json"), &json);
    if std::env::var("DATADIFF_BENCH_BASELINE").as_deref() == Ok("1") {
        let _ = std::fs::write("BENCH_baseline.json", &json);
        println!("\nwrote BENCH_baseline.json");
    }
}

/// Shared fixture: 64 warm nodes, 10K files cached round-robin, 50K-deep
/// queue of single-file tasks (the paper's §5.1 shape at 20% task scale).
struct SchedFixture {
    reg: ExecutorRegistry,
    index: LocationIndex,
    queue: WaitQueue,
    pending: PendingIndex,
    execs: Vec<ExecutorId>,
}

fn sched_fixture(caching: bool) -> SchedFixture {
    let mut reg = ExecutorRegistry::new();
    let mut index = LocationIndex::new();
    let mut rng = Pcg64::seeded(1);
    let execs: Vec<ExecutorId> = (0..64).map(|_| reg.register(2, Micros::ZERO)).collect();
    // Warm index: every file cached somewhere.
    for f in 0..10_000u32 {
        index.add(FileId(f), *rng.choose(&execs));
    }
    let mut queue = WaitQueue::new();
    let mut pending = PendingIndex::new();
    for i in 0..50_000u64 {
        let qref = queue.push_back(Task {
            id: TaskId(i),
            files: vec![FileId(rng.below(10_000) as u32)],
            compute: Micros::ZERO,
            arrival: Micros::ZERO,
        });
        if caching {
            pending.on_push(&queue, qref, &index);
        }
    }
    SchedFixture {
        reg,
        index,
        queue,
        pending,
        execs,
    }
}

/// One phase-2 pickup on a warm 64-node cluster with a deep queue —
/// the indexed (sub-linear) path the engines run.
fn bench_scheduler_decision(counters: &mut Vec<(String, f64)>) -> Bench {
    let mut b = Bench::new("scheduler pick_tasks (64 nodes, warm index)");
    for policy in [
        DispatchPolicy::FirstAvailable,
        DispatchPolicy::MaxComputeUtil,
        DispatchPolicy::GoodCacheCompute,
    ] {
        let mut fx = sched_fixture(policy.uses_caching());
        let mut sched = Scheduler::new(SchedulerConfig {
            policy,
            ..SchedulerConfig::default()
        });
        let mut e = 0usize;
        b.iter(policy.name(), 1, || {
            e = (e + 1) % fx.execs.len();
            let got = sched.pick_tasks(
                fx.execs[e],
                1,
                &mut fx.queue,
                &mut fx.pending,
                &fx.reg,
                &fx.index,
            );
            // Re-queue so the bench is steady-state.
            for t in got {
                let qref = fx.queue.push_back(t);
                if policy.uses_caching() {
                    fx.pending.on_push(&fx.queue, qref, &fx.index);
                }
            }
        });
        let per_pickup = sched.stats.tasks_inspected as f64 / sched.stats.pickups.max(1) as f64;
        println!(
            "    {}: {:.1} tasks inspected/pickup (window would be {})",
            policy.name(),
            per_pickup,
            sched.window_size(&fx.reg)
        );
        if policy.uses_caching() {
            counters.push((format!("inspected_per_pickup/{}", policy.name()), per_pickup));
        }
    }
    let _ = b.write_csv();
    b
}

/// The same decision through the retained O(min(|Q|, W)) reference scan —
/// the before/after contrast for §Perf iteration 3 (decision parity is
/// asserted by the sched_parity test; this measures only cost).
fn bench_scheduler_reference_scan() -> Bench {
    let mut b = Bench::new("scheduler reference window scan (64 nodes, warm index)");
    for policy in [DispatchPolicy::MaxComputeUtil, DispatchPolicy::GoodCacheCompute] {
        let mut fx = sched_fixture(true);
        let sched = Scheduler::new(SchedulerConfig {
            policy,
            ..SchedulerConfig::default()
        });
        let mut e = 0usize;
        b.iter(policy.name(), 1, || {
            e = (e + 1) % fx.execs.len();
            let refs =
                sched.pick_refs_reference(fx.execs[e], 1, &fx.queue, &fx.reg, &fx.index);
            // Mirror the indexed bench's churn: remove + re-queue.
            for r in refs {
                let t = datadiffusion::coordinator::pending::remove_queued(
                    &mut fx.queue,
                    &mut fx.pending,
                    r,
                    &fx.index,
                );
                let qref = fx.queue.push_back(t);
                fx.pending.on_push(&fx.queue, qref, &fx.index);
            }
        });
    }
    let _ = b.write_csv();
    b
}

/// Fixture for the hot-file maintenance contrast: 2 000 queued readers
/// of one popular file plus 40 medium files (17 readers each — above the
/// eager-apply cap, so they defer too and can overflow a patch log).
fn pending_fixture(lazy: bool) -> (WaitQueue, LocationIndex, PendingIndex, Vec<ExecutorId>) {
    let index = LocationIndex::new();
    let mut queue = WaitQueue::new();
    let mut pending = if lazy {
        PendingIndex::new()
    } else {
        PendingIndex::eager()
    };
    let mut id = 0u64;
    for _ in 0..2_000 {
        let qref = queue.push_back(Task {
            id: TaskId(id),
            files: vec![FileId(0)],
            compute: Micros::ZERO,
            arrival: Micros::ZERO,
        });
        pending.on_push(&queue, qref, &index);
        id += 1;
    }
    for f in 1..=40u32 {
        for _ in 0..17 {
            let qref = queue.push_back(Task {
                id: TaskId(id),
                files: vec![FileId(f)],
                compute: Micros::ZERO,
                arrival: Micros::ZERO,
            });
            pending.on_push(&queue, qref, &index);
            id += 1;
        }
    }
    let execs: Vec<ExecutorId> = (0..8u32).map(ExecutorId).collect();
    (queue, index, pending, execs)
}

/// Hot-file candidate maintenance, lazy vs eager (ROADMAP "bound
/// hot-file pending maintenance"): a cache insert/evict of a file with
/// 2K pending readers is O(1) bookkeeping on the lazy path and an
/// O(readers) walk on the eager reference. Wall times are measured per
/// churn event (including the event's share of consults); the
/// deterministic op counters below feed the lazy ≤ eager CI gate.
fn bench_pending_maintenance(counters: &mut Vec<(String, f64)>) -> Bench {
    let mut b = Bench::new("pending index maintenance (hot file, 2K readers)");
    let hot = FileId(0);
    for lazy in [true, false] {
        let (queue, mut index, mut pending, execs) = pending_fixture(lazy);
        let mut r = 0u64;
        let label = if lazy {
            "lazy churn event (+consult every 7)"
        } else {
            "eager churn event (+consult every 7)"
        };
        // Consult stride 7 is coprime with the 8-executor rotation, so
        // refreshes visit every executor (a multiple of 8 would pin all
        // consults — and hence all lazy patch cost — to execs[0]).
        b.iter(label, 1, || {
            let e = execs[(r % execs.len() as u64) as usize];
            index.add(hot, e);
            pending.on_index_add(hot, e);
            index.remove(hot, e);
            pending.on_index_remove(hot, e, &queue, &index);
            if r % 7 == 0 {
                pending.refresh(e, &queue, &index);
            }
            r += 1;
        });
    }

    // Deterministic pass: a fixed churn trace driven through both modes,
    // so the counters are machine-independent. 1 000 hot add/evict
    // cycles (2 000 index events) with a consult every 7 cycles (coprime
    // with the 8-executor rotation, so every executor pays consult-time
    // patches), then 40 medium-file inserts at one executor (overflowing
    // the lazy patch log) and a final settle-everything consult round.
    let drive = |lazy: bool| -> (PendingStats, u64) {
        let (mut queue, mut index, mut pending, execs) = pending_fixture(lazy);
        let mut events = 0u64;
        for r in 0..1_000u64 {
            let e = execs[(r % execs.len() as u64) as usize];
            index.add(hot, e);
            pending.on_index_add(hot, e);
            index.remove(hot, e);
            pending.on_index_remove(hot, e, &queue, &index);
            events += 2;
            if r % 7 == 0 {
                pending.refresh(e, &queue, &index);
            }
        }
        for f in 1..=40u32 {
            index.add(FileId(f), execs[0]);
            pending.on_index_add(FileId(f), execs[0]);
            events += 1;
        }
        for &e in &execs {
            pending.refresh(e, &queue, &index);
        }
        // Dead-hint phase (ROADMAP "dead-hint accounting"): cache the hot
        // file at execs[0] and settle its candidate set, evict it again
        // (deferred on the lazy path), then drain head readers while the
        // eviction is still pending — their candidate entries die in
        // place (the file has no holders at removal time, so nothing
        // sweeps them). One real pickup then purges the dead hints on
        // encounter. The eager reference retracts at event time, so it
        // purges nothing — `pending/dead_hints_purged` is a lazy-only
        // counter and the CI gate asserts it stays live (> 0) here.
        let e0 = execs[0];
        index.add(hot, e0);
        pending.on_index_add(hot, e0);
        pending.refresh(e0, &queue, &index);
        index.remove(hot, e0);
        pending.on_index_remove(hot, e0, &queue, &index);
        events += 2;
        for _ in 0..8 {
            let qref = queue.front_ref().expect("fixture queue is non-empty");
            remove_queued(&mut queue, &mut pending, qref, &index);
        }
        // Slab-churn phase (ROADMAP "arena slab reuse"): executors leave
        // and rejoin while the hot file still has pending readers. Each
        // deregistration parks the freed candidate set in the pool, and
        // the rejoin's first index event must re-register through that
        // pool instead of allocating — `pending/slab_reuse` counts the
        // recycled sets and the CI gate asserts it stays live (> 0).
        for round in 0..4usize {
            let e = execs[1 + (round % 3)];
            pending.on_deregister(e);
            index.add(hot, e);
            pending.on_index_add(hot, e);
            index.remove(hot, e);
            pending.on_index_remove(hot, e, &queue, &index);
            events += 2;
        }
        let mut reg = ExecutorRegistry::new();
        for _ in 0..execs.len() {
            reg.register(2, Micros::ZERO);
        }
        let mut sched = Scheduler::new(SchedulerConfig {
            policy: DispatchPolicy::MaxComputeUtil,
            ..SchedulerConfig::default()
        });
        black_box(sched.pick_tasks(e0, 1, &mut queue, &mut pending, &reg, &index));
        (pending.stats.clone(), events)
    };
    let (lazy_stats, events) = drive(true);
    let (eager_stats, _) = drive(false);
    println!(
        "    maintenance ops over {events} events: lazy {} (rebuilds {}, \
         dirty {}, dead hints purged {}) vs eager {} (purged {})",
        lazy_stats.maintenance_ops,
        lazy_stats.epoch_rebuilds,
        lazy_stats.dirty_records,
        lazy_stats.dead_hints_purged,
        eager_stats.maintenance_ops,
        eager_stats.dead_hints_purged
    );
    assert_eq!(
        eager_stats.dead_hints_purged, 0,
        "eager maintenance must never create dead hints"
    );
    counters.push((
        "pending/maintenance_ops".into(),
        lazy_stats.maintenance_ops as f64,
    ));
    counters.push((
        "pending/eager_maintenance_ops".into(),
        eager_stats.maintenance_ops as f64,
    ));
    counters.push((
        "pending/maintenance_ops_per_event".into(),
        lazy_stats.maintenance_ops as f64 / events.max(1) as f64,
    ));
    counters.push((
        "pending/eager_maintenance_ops_per_event".into(),
        eager_stats.maintenance_ops as f64 / events.max(1) as f64,
    ));
    counters.push((
        "pending/epoch_rebuilds".into(),
        lazy_stats.epoch_rebuilds as f64,
    ));
    counters.push((
        "pending/dead_hints_purged".into(),
        lazy_stats.dead_hints_purged as f64,
    ));
    counters.push((
        "pending/dead_hints_purged_per_event".into(),
        lazy_stats.dead_hints_purged as f64 / events.max(1) as f64,
    ));
    counters.push(("pending/slab_reuse".into(), lazy_stats.slab_reuse as f64));
    let _ = b.write_csv();
    b
}

/// Notify-side reuse (ROADMAP "notify-side pending reuse"): repeated
/// phase-1 decisions for one multi-file head must reuse the memoized
/// (overlap, id) ranking — `holder_recounts` is the tripwire for the
/// retired per-call recount and must stay 0.
fn bench_notify(counters: &mut Vec<(String, f64)>) -> Bench {
    let mut b = Bench::new("scheduler select_notify (64 nodes, warm index)");
    let mut fx = sched_fixture(true);
    let mut sched = Scheduler::new(SchedulerConfig {
        policy: DispatchPolicy::GoodCacheCompute,
        ..SchedulerConfig::default()
    });
    let single = [FileId(1)];
    b.iter("single-file head (bitset fast path)", 1, || {
        black_box(sched.select_notify(&single, &fx.reg, &mut fx.pending, &fx.index));
    });
    let multi = [FileId(1), FileId(2), FileId(3)];
    b.iter("3-file head (memoized ranking)", 1, || {
        black_box(sched.select_notify(&multi, &fx.reg, &mut fx.pending, &fx.index));
    });

    // Deterministic pass for the counters.
    let mut fx = sched_fixture(true);
    let mut sched = Scheduler::new(SchedulerConfig {
        policy: DispatchPolicy::GoodCacheCompute,
        ..SchedulerConfig::default()
    });
    for _ in 0..1_000u32 {
        black_box(sched.select_notify(&multi, &fx.reg, &mut fx.pending, &fx.index));
    }
    let hits = fx.pending.stats.notify_memo_hits;
    let builds = fx.pending.stats.notify_memo_builds;
    println!(
        "    1000 decisions, one head: {builds} ranking build(s), {hits} memo hits, \
         {} holder recounts",
        sched.stats.holder_recounts
    );
    counters.push((
        "notify/holder_recounts".into(),
        sched.stats.holder_recounts as f64,
    ));
    counters.push(("notify/memo_builds".into(), builds as f64));
    counters.push((
        "notify/memo_hits_per_decision".into(),
        hits as f64 / sched.stats.notify_decisions.max(1) as f64,
    ));
    let _ = b.write_csv();
    b
}

fn bench_waitqueue(counters: &mut Vec<(String, f64)>) -> Bench {
    let mut b = Bench::new("wait-queue ops");
    let mut q = WaitQueue::new();
    for i in 0..100_000u64 {
        q.push_back(Task {
            id: TaskId(i),
            files: vec![FileId(i as u32)],
            compute: Micros::ZERO,
            arrival: Micros::ZERO,
        });
    }
    b.iter("push+pop", 1, || {
        let t = q.pop_front().expect("non-empty");
        q.push_back(t);
    });
    b.iter("window scan 3200", 3200, || {
        let n = q.window(3200).count();
        black_box(n);
    });
    b.iter("window boundary seq (amortized)", 1, || {
        // Steady-state churn: one pop + one push per query, like the
        // scheduler's per-pickup pattern.
        let t = q.pop_front().expect("non-empty");
        q.push_back(t);
        black_box(q.window_boundary_seq(3200));
    });
    // ROADMAP "scheduler stats for boundary cursor": cold seeks must stay
    // rare and warm repositioning ~O(1) steps per query, or the
    // sub-linear pickup's amortization argument has regressed.
    let bs = &q.boundary_stats;
    println!(
        "    boundary cursor: {} queries, {} cold seeks ({} steps), \
         {:.3} amortized steps/query",
        bs.queries,
        bs.cold_seeks,
        bs.cold_seek_steps,
        bs.amortized_steps_per_query()
    );
    counters.push(("boundary/queries".into(), bs.queries as f64));
    counters.push(("boundary/cold_seeks".into(), bs.cold_seeks as f64));
    counters.push(("boundary/cold_seek_steps".into(), bs.cold_seek_steps as f64));
    counters.push((
        "boundary/amortized_steps_per_query".into(),
        bs.amortized_steps_per_query(),
    ));
    let _ = b.write_csv();
    b
}

fn bench_cache() -> Bench {
    let mut b = Bench::new("object cache (LRU, 4GB, 10MB objects)");
    let mut cache = ObjectCache::new(CacheConfig {
        capacity_bytes: 4_000_000_000,
        policy: EvictionPolicy::Lru,
    });
    let mut rng = Pcg64::seeded(2);
    for f in 0..400u32 {
        cache.insert(FileId(f), 10_000_000, &mut rng);
    }
    b.iter("touch (hit)", 1, || {
        let f = FileId(rng.below(400) as u32);
        black_box(cache.touch(f));
    });
    b.iter("insert (evicting)", 1, || {
        let f = FileId(400 + rng.below(10_000) as u32);
        black_box(cache.insert(f, 10_000_000, &mut rng));
    });
    let _ = b.write_csv();
    b
}

fn mode_name(mode: RerateMode) -> &'static str {
    match mode {
        RerateMode::Batched => "batched",
        RerateMode::Reference => "reference",
    }
}

/// Transfer churn on a shared bottleneck link: the batched rerate path
/// (what the engine runs) against the retained per-event reference. The
/// per-event work counters are deterministic, so the CI gate asserts
/// batched ≤ reference regardless of machine noise.
fn bench_flownet(counters: &mut Vec<(String, f64)>) -> Bench {
    let mut b = Bench::new("flow network transfer churn");
    for mode in [RerateMode::Batched, RerateMode::Reference] {
        for concurrency in [16usize, 128] {
            let mut net = FlowNet::with_mode(mode);
            let gpfs = net.add_link(5.5e8);
            let nics: Vec<_> = (0..64).map(|_| net.add_link(1.25e8)).collect();
            let mut now = Micros::ZERO;
            let mut i = 0u64;
            // Prime with `concurrency` in-flight transfers.
            for _ in 0..concurrency {
                net.start(now, 10_000_000, &[gpfs, nics[(i % 64) as usize]], i);
                i += 1;
            }
            let mut events = 0u64;
            let label = format!("{} start+complete @ {concurrency} concurrent", mode_name(mode));
            b.iter(&label, 1, || {
                let t = net.next_completion().expect("in flight");
                now = t;
                net.pop_completion(t);
                net.start(now, 10_000_000, &[gpfs, nics[(i % 64) as usize]], i);
                i += 1;
                events += 2;
            });
            counters.push((
                format!("flow/{}_rerates_per_event@{concurrency}", mode_name(mode)),
                net.stats.transfer_rerates as f64 / events.max(1) as f64,
            ));
            counters.push((
                format!("flow/{}_heap_updates_per_event@{concurrency}", mode_name(mode)),
                net.stats.heap_updates as f64 / events.max(1) as f64,
            ));
        }
    }
    let _ = b.write_csv();
    b
}

/// A 4-shard router with two nodes per shard and generous caches.
fn shard_fixture() -> ShardedCoordinator {
    let mut r = ShardedCoordinator::new(
        CoreConfig {
            scheduler: SchedulerConfig::default(),
            provisioner: ProvisionerConfig::default(),
            cache: CacheConfig {
                capacity_bytes: 1 << 30, // no eviction: deterministic crossings
                policy: EvictionPolicy::Lru,
            },
            max_nodes: 8,
            slots_per_node: 2,
            file_sizes: FileSizes::Uniform(10_000_000),
        },
        4,
        Pcg64::seeded(9),
    );
    for _ in 0..8 {
        let (_, effs) = r.register_node(Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
    }
    r
}

/// `rounds` per-shard home files: `homes[r][s]` is the r-th file whose
/// dominant-file hash lands on shard `s`.
fn shard_home_files(r: &ShardedCoordinator, rounds: usize) -> Vec<Vec<FileId>> {
    let mut per_shard: Vec<Vec<FileId>> = vec![Vec::new(); 4];
    let mut f = 0u32;
    while per_shard.iter().any(|v| v.len() < rounds) {
        let s = r.shard_of_file(FileId(f));
        if per_shard[s].len() < rounds {
            per_shard[s].push(FileId(f));
        }
        f += 1;
    }
    (0..rounds)
        .map(|round| (0..4).map(|s| per_shard[s][round]).collect())
        .collect()
}

/// Multi-coordinator sharding (ROADMAP "multi-coordinator sharding"): a
/// 4-shard router fanning a cross-shard workload — every round seeds one
/// fresh file per shard, then submits every ordered cross-shard pair, so
/// each secondary fetch must be rewritten from a GPFS miss into a
/// cross-shard peer fetch. Wall time measures router fan-in overhead;
/// the deterministic `shard/*` counters feed the CI gate (cross fetches
/// must fire, and never exceed one per routed task).
fn bench_sharded_router(counters: &mut Vec<(String, f64)>) -> Bench {
    let mut b = Bench::new("sharded coordinator router (K=4)");
    // Timed: steady-state single-file task round trips through the
    // router (arrival → notify → pickup → fetch → compute → done).
    let mut r = shard_fixture();
    let warm = shard_home_files(&r, 1);
    let mut id = 0u64;
    b.iter("task round trip through the router", 1, || {
        let task = Task {
            id: TaskId(id),
            files: vec![warm[0][(id % 4) as usize]],
            compute: Micros::ZERO,
            arrival: Micros::ZERO,
        };
        id += 1;
        let effs = r.on_arrival(task, 0, 0.0, Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
    });

    // Deterministic pass: 8 rounds × (4 seed tasks + 12 cross-shard
    // pair tasks); every pair task's secondary file lives only on a
    // foreign shard, so each round contributes exactly 12 rewrites.
    let mut r = shard_fixture();
    let rounds = shard_home_files(&r, 8);
    let mut id = 0u64;
    for homes in &rounds {
        for &f in homes {
            let effs = r.on_arrival(
                Task {
                    id: TaskId(id),
                    files: vec![f],
                    compute: Micros::ZERO,
                    arrival: Micros::ZERO,
                },
                0,
                0.0,
                Micros::ZERO,
            );
            id += 1;
            r.drain_effects(effs, Micros::ZERO);
        }
        for s in 0..4usize {
            for t in 0..4usize {
                if s == t {
                    continue;
                }
                let effs = r.on_arrival(
                    Task {
                        id: TaskId(id),
                        files: vec![homes[s], homes[t]],
                        compute: Micros::ZERO,
                        arrival: Micros::ZERO,
                    },
                    0,
                    0.0,
                    Micros::ZERO,
                );
                id += 1;
                r.drain_effects(effs, Micros::ZERO);
            }
        }
    }
    let c = r.counters();
    assert!(
        c.cross_fetches > 0,
        "cross-shard fixture produced no rewrites"
    );
    println!(
        "    {} router events, {} cross fetches over {} tasks \
         ({:.3} per task), {} cross bytes",
        c.router_events,
        c.cross_fetches,
        c.tasks_routed(),
        c.cross_fetches_per_task(),
        c.cross_bytes
    );
    counters.push(("shard/router_events".into(), c.router_events as f64));
    counters.push(("shard/cross_fetches".into(), c.cross_fetches as f64));
    counters.push((
        "shard/cross_fetches_per_task".into(),
        c.cross_fetches_per_task(),
    ));
    let _ = b.write_csv();
    b
}

/// The sharded live engine end-to-end: K=2 real worker pools behind the
/// router over an on-disk dataset, with one multi-input task per shard
/// whose secondary file is homed on the *other* shard — every run
/// performs real cross-shard worker-to-worker copies. Wall time tracks
/// thread/filesystem overhead per run; the deterministic `live/*`
/// counters feed the CI gate (every shard's pool must be staffed,
/// cross-shard copies must move real bytes).
fn bench_live(counters: &mut Vec<(String, f64)>) -> Bench {
    use datadiffusion::live::{self, ComputeKind, LiveConfig, LiveFaults, LiveTask};
    let mut b = Bench::new("live engine (K=2 sharded worker pools)")
        .samples(2)
        .min_sample_duration(std::time::Duration::from_millis(1));

    const K: usize = 2;
    const BYTES: u64 = 4096;
    // The router's home hash is a pure function of K: probe it for one
    // file id per shard.
    let probe = ShardedCoordinator::new(
        CoreConfig {
            scheduler: SchedulerConfig::default(),
            provisioner: ProvisionerConfig::default(),
            cache: CacheConfig::lru(1 << 20),
            max_nodes: K,
            slots_per_node: 1,
            file_sizes: FileSizes::Uniform(BYTES),
        },
        K,
        Pcg64::seeded(1),
    );
    let mut homes: Vec<Option<FileId>> = vec![None; K];
    for raw in 0..4096u32 {
        let f = FileId(raw);
        let s = probe.shard_of_file(f);
        if homes[s].is_none() {
            homes[s] = Some(f);
        }
        if homes.iter().all(Option::is_some) {
            break;
        }
    }
    let homes: Vec<FileId> = homes.into_iter().map(|h| h.expect("home file")).collect();

    let root = std::env::temp_dir().join(format!("dd-bench-live-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let store = root.join("store");
    std::fs::create_dir_all(&store).expect("store dir");
    let name_of = |f: FileId| format!("f{}.bin", f.0);
    for &f in &homes {
        std::fs::write(store.join(name_of(f)), vec![f.0 as u8; BYTES as usize])
            .expect("dataset");
    }
    // Singles seed each shard's cache; the trailing pairs then chain a
    // fetch of the other shard's (cached) file — a cross-shard copy.
    let mut tasks: Vec<LiveTask> = Vec::new();
    for _ in 0..3 {
        for &f in &homes {
            tasks.push(LiveTask::single(name_of(f), f));
        }
    }
    for s in 0..K {
        let foreign = homes[(s + 1) % K];
        tasks.push(LiveTask {
            file_name: name_of(homes[s]),
            file: homes[s],
            extra: vec![(foreign, name_of(foreign))],
        });
    }
    let cfg_for = |cache_root: std::path::PathBuf| LiveConfig {
        initial_workers: K,
        max_workers: K,
        queue_tasks_per_worker: usize::MAX >> 8,
        allocation: AllocationPolicy::OneAtATime,
        policy: DispatchPolicy::GoodCacheCompute,
        cache: CacheConfig::lru(1 << 20),
        persistent_dir: store.clone(),
        cache_root,
        compute: ComputeKind::Sleep(std::time::Duration::from_millis(2)),
        seed: 77,
        idle_release_s: 0.0,
        shards: K,
        faults: LiveFaults::default(),
    };
    let mut runs = 0u64;
    b.iter("sharded live run (8 tasks, 2 pools)", 1, || {
        runs += 1;
        let r = live::run(&cfg_for(root.join(format!("c{runs}"))), &tasks)
            .expect("live bench run");
        black_box(r.completed);
    });

    // Deterministic pass: one more run feeds the gated counters.
    let report = live::run(&cfg_for(root.join("final")), &tasks).expect("live bench run");
    assert_eq!(report.completed, tasks.len() as u64, "live bench lost tasks");
    assert!(
        report.shard.cross_fetches > 0,
        "live bench produced no cross-shard copies"
    );
    let min_pool = report.workers_per_shard.iter().copied().min().unwrap_or(0);
    println!(
        "    {} tasks, {} cross fetches moving {} bytes, pools {:?}",
        report.completed, report.shard.cross_fetches, report.shard.cross_bytes,
        report.workers_per_shard
    );
    counters.push(("live/workers_per_shard".into(), min_pool as f64));
    counters.push(("live/cross_copy_bytes".into(), report.shard.cross_bytes as f64));
    counters.push(("live/cross_fetches".into(), report.shard.cross_fetches as f64));
    let _ = std::fs::remove_dir_all(&root);
    let _ = b.write_csv();
    b
}

/// Chaos harness end-to-end: a seeded fault schedule through the
/// coordinator with the shadow-state oracle checking after every event.
/// The counters gate CI (`tools/bench_gate.py`): every run must inject
/// faults (`chaos/faults_injected > 0`) and the oracle must stay silent
/// (`chaos/oracle_violations == 0`).
fn bench_chaos(counters: &mut Vec<(String, f64)>) -> Bench {
    use datadiffusion::chaos::{run_chaos, ChaosConfig};
    let mut b = Bench::new("chaos harness (quick run, shadow oracle)")
        .samples(3)
        .min_sample_duration(std::time::Duration::from_millis(1));
    let mut seed = 0u64;
    b.iter("seeded quick run (60 events)", 60, || {
        seed += 1;
        let r = run_chaos(&ChaosConfig::quick(seed));
        black_box(r.fingerprint);
    });
    // Deterministic pass: a fixed 4-seed block at K=1 and K=4 feeds the
    // gated counters (the schedule is seed-pure, so these never wobble).
    let mut faults = 0u64;
    let mut violations = 0usize;
    let mut runs = 0u64;
    for seed in 0..4u64 {
        for shards in [1usize, 4] {
            let mut cfg = ChaosConfig::quick(900 + seed);
            cfg.shards = shards;
            if shards > 1 {
                cfg.nodes = 8;
            }
            let r = run_chaos(&cfg);
            assert!(!r.stalled, "chaos bench run stalled (seed {})", r.seed);
            faults += r.faults_injected;
            violations += r.oracle_violations;
            runs += 1;
        }
    }
    println!(
        "    {runs} chaos runs: {faults} faults injected, {violations} oracle violation(s)"
    );
    counters.push(("chaos/faults_injected".into(), faults as f64));
    counters.push(("chaos/oracle_violations".into(), violations as f64));
    counters.push((
        "chaos/faults_injected_per_run".into(),
        faults as f64 / runs as f64,
    ));
    let _ = b.write_csv();
    b
}

/// Workload scenario generation: all four seeded families from the
/// scenario library (zipf-churn, diurnal, bulk-batch, pipeline) at a
/// fixed size. Wall times track the generator cost per family; the
/// deterministic `workload/*` counters feed the CI gate — the library
/// must keep producing tasks (`workload/tasks_generated > 0`) and the
/// pipeline family must keep emitting dependency edges
/// (`workload/dep_edges > 0`, else arrival gating is vacuously dead);
/// `workload/dep_edges_per_task` is baseline-gated against drift.
fn bench_scenario_generation(counters: &mut Vec<(String, f64)>) -> Bench {
    use datadiffusion::config::{ScenarioSpec, WorkloadConfig};
    use datadiffusion::workload::{self, Workload};

    let generate = |name: &str, num_tasks: u64| -> Workload {
        let spec = ScenarioSpec::preset(name).expect("catalog name");
        let mut wcfg = WorkloadConfig::default();
        wcfg.num_tasks = num_tasks;
        wcfg.num_files = 400;
        wcfg.scenario = Some(spec);
        workload::generate(&wcfg, 42)
    };

    let mut b = Bench::new("workload scenario generation (4 families)")
        .samples(3)
        .min_sample_duration(std::time::Duration::from_millis(1));
    for name in ScenarioSpec::CATALOG {
        b.iter(&format!("{name} (5K tasks)"), 5_000, || {
            black_box(generate(name, 5_000).fingerprint());
        });
    }

    // Deterministic pass: the counters aggregate one fixed-seed
    // generation per family, so they never wobble across machines.
    let mut tasks_generated = 0u64;
    let mut dep_edges = 0u64;
    for name in ScenarioSpec::CATALOG {
        let wl = generate(name, 5_000);
        assert!(!wl.tasks.is_empty(), "{name} generated no tasks");
        tasks_generated += wl.tasks.len() as u64;
        dep_edges += wl.dep_edges;
    }
    println!(
        "    4 families: {tasks_generated} tasks, {dep_edges} dep edges \
         ({:.4} per task)",
        dep_edges as f64 / tasks_generated.max(1) as f64
    );
    counters.push(("workload/tasks_generated".into(), tasks_generated as f64));
    counters.push(("workload/dep_edges".into(), dep_edges as f64));
    counters.push((
        "workload/dep_edges_per_task".into(),
        dep_edges as f64 / tasks_generated.max(1) as f64,
    ));
    let _ = b.write_csv();
    b
}

/// Model-predictive provisioning (`--allocation model`,
/// docs/PROVISIONING.md): one timed control step (estimate over the
/// recorder window + the §3 solve over a 64-node range), then two
/// deterministic passes feeding the gated `model/*` counters — a seeded
/// regime shift that must move the adopted target through the deadband
/// (`model/target_changes > 0`), and a K=4 router under one-sided load
/// that must move per-shard quotas toward the pressure
/// (`model/shard_rebalances > 0`).
fn bench_model_controller(counters: &mut Vec<(String, f64)>) -> Bench {
    use datadiffusion::coordinator::model::{ModelController, ModelControllerConfig};
    use datadiffusion::metrics::Recorder;

    let mut b = Bench::new("model-predictive controller (estimate + solve)");
    // Timed: a full control step over a warm 120 s signal window.
    let mut rec = Recorder::default();
    for s in 0..120u64 {
        let now = Micros::from_secs(s);
        let bkt = rec.ts.bucket_mut(s);
        bkt.arrivals += 40;
        bkt.bytes_local += 6_000_000;
        bkt.bytes_gpfs += 1_000_000;
        rec.sample(now, 100, 8, 10, 16);
    }
    let mut ctl = ModelController::new(ModelControllerConfig::default(), 2, 1e7);
    b.iter("decide (64-node range, warm window)", 1, || {
        black_box(ctl.decide(&rec, 100, 64));
    });

    // Deterministic pass 1: 30 s at 40 tasks/s then a 10x surge
    // (window_s = 1 so the estimate follows each bucket, as in the unit
    // suite). The surge must punch through the deadband and move the
    // adopted target — a frozen controller would hold it forever.
    let mut ctl = ModelController::new(
        ModelControllerConfig {
            window_s: 1,
            ..ModelControllerConfig::default()
        },
        2,
        1e7,
    );
    let mut rec = Recorder::default();
    for s in 0..60u64 {
        let now = Micros::from_secs(s);
        rec.ts.bucket_mut(s).arrivals += if s < 30 { 40 } else { 400 };
        rec.sample(now, 50, 4, 4, 8);
        black_box(ctl.decide(&rec, 50, 64));
    }
    let stats = ctl.stats.clone();
    assert!(
        stats.target_changes > 0,
        "the 10x arrival surge must move the adopted target"
    );

    // Deterministic pass 2: a K = 4 router under `--allocation model`
    // with every task homed on one shard; the pre-tick rebalance must
    // move quota toward the loaded shard.
    let mut r = ShardedCoordinator::new(
        CoreConfig {
            scheduler: SchedulerConfig::default(),
            provisioner: ProvisionerConfig {
                allocation: AllocationPolicy::Model,
                ..ProvisionerConfig::default()
            },
            cache: CacheConfig {
                capacity_bytes: 1 << 30,
                policy: EvictionPolicy::Lru,
            },
            max_nodes: 8,
            slots_per_node: 2,
            file_sizes: FileSizes::Uniform(10_000_000),
        },
        4,
        Pcg64::seeded(11),
    );
    for _ in 0..8 {
        let (_, effs) = r.register_node(Micros::ZERO);
        r.drain_effects(effs, Micros::ZERO);
    }
    let hot = shard_home_files(&r, 1)[0][0];
    let mut id = 0u64;
    for s in 0..4u64 {
        let now = Micros::from_secs(s);
        for _ in 0..40 {
            let effs = r.on_arrival(
                Task {
                    id: TaskId(id),
                    files: vec![hot],
                    compute: Micros::from_millis(100),
                    arrival: now,
                },
                0,
                0.0,
                now,
            );
            id += 1;
            r.drain_effects(effs, now);
        }
        let effs = r.on_tick(now);
        r.drain_effects(effs, now);
    }
    let merged = r.merged_model_stats().expect("model allocation is on");
    let rebalances = r.quota_rebalances();
    assert!(
        rebalances > 0,
        "one-sided load must move quota between shards"
    );
    let solves = stats.solves + merged.solves;
    let changes = stats.target_changes + merged.target_changes;
    let holds = stats.deadband_holds + merged.deadband_holds;
    println!(
        "    controller: {solves} solves, {changes} target changes, \
         {holds} deadband holds; router: {rebalances} quota rebalances"
    );
    counters.push(("model/solves".into(), solves as f64));
    counters.push(("model/target_changes".into(), changes as f64));
    counters.push(("model/deadband_holds".into(), holds as f64));
    counters.push((
        "model/target_changes_per_decision".into(),
        changes as f64 / solves.max(1) as f64,
    ));
    counters.push(("model/shard_rebalances".into(), rebalances as f64));
    let _ = b.write_csv();
    b
}

/// Uniform data-object size (bytes) in the million-task scale drive.
const SCALE_FILE_BYTES: u64 = 1_000_000;

/// Pump the effect queue to quiescence: enact every effect through the
/// matching handler, returning each drained `Vec` to the core's scratch
/// pool, and fall back to `kick()` while tasks remain queued (a notify
/// may decline; the safety net re-notifies). Mirrors the engines'
/// recycle discipline, so `alloc_events` measures real pool behavior.
fn scale_drain(core: &mut CoordinatorCore, q: &mut std::collections::VecDeque<Effect>, now: Micros) {
    let mut kicks = 0u32;
    loop {
        while let Some(eff) = q.pop_front() {
            let mut effs = match eff {
                Effect::Notify(e) => core.on_pickup(e, now),
                Effect::Fetch(plan) => core.on_fetch_done(plan.task_id, now, None),
                Effect::Compute { task_id, .. } => core.on_compute_done(task_id, now, now),
                // The fleet is fully registered up front and never
                // idle-released (no ticks), so these are no-ops here.
                Effect::Allocate(_) | Effect::Release(_) => continue,
            };
            q.extend(effs.drain(..));
            core.recycle_effects(effs);
        }
        if core.queue_is_empty() {
            return;
        }
        kicks += 1;
        assert!(kicks < 64, "scale drive stalled: queue non-empty after 64 kicks");
        let mut effs = core.kick();
        q.extend(effs.drain(..));
        core.recycle_effects(effs);
    }
}

/// The tentpole's proof: a seeded million-task × 1K-executor drive
/// through the arena/SoA dispatch path (100K × 128 with
/// `DATADIFF_SCALE_QUICK=1`, the CI smoke shape). Every arrival →
/// notify → pickup → fetch → compute round trip runs synchronously with
/// the engines' buffer-recycling discipline, and three gated `scale/*`
/// counters prove the budget holds:
///
/// * `scale/events_per_sec` — handler-event throughput (wall-clock; the
///   gate only requires it to be present and positive);
/// * `scale/allocs_per_event` — scratch-pool misses per handler event,
///   a deterministic allocation-rate proxy that must stay under the
///   gate's constant (a recycling regression shows up here regardless
///   of machine noise);
/// * `scale/peak_table_bytes` — peak arena table footprint (index +
///   pending + caches) sampled once per submission batch.
fn bench_scale(counters: &mut Vec<(String, f64)>) -> Bench {
    use std::collections::VecDeque;
    use std::time::Instant;

    let quick = std::env::var("DATADIFF_SCALE_QUICK").as_deref() == Ok("1");
    let (tasks, nodes, files) = if quick {
        (100_000u64, 128usize, 10_000u64)
    } else {
        (1_000_000u64, 1_000usize, 100_000u64)
    };
    let mut b = Bench::new(if quick {
        "million-task scale drive (quick: 100K tasks, 128 executors)"
    } else {
        "million-task scale drive (1M tasks, 1K executors)"
    });
    let mut core = CoordinatorCore::new(
        CoreConfig {
            scheduler: SchedulerConfig::default(),
            provisioner: ProvisionerConfig::default(),
            // ~200 objects per node: eviction churn is part of the load.
            cache: CacheConfig::lru(200 * SCALE_FILE_BYTES),
            max_nodes: nodes,
            slots_per_node: 2,
            file_sizes: FileSizes::Uniform(SCALE_FILE_BYTES),
        },
        Pcg64::seeded(4242),
    );
    let mut q: VecDeque<Effect> = VecDeque::new();
    for _ in 0..nodes {
        let (_, mut effs) = core.register_node(Micros::ZERO);
        q.extend(effs.drain(..));
        core.recycle_effects(effs);
    }
    scale_drain(&mut core, &mut q, Micros::ZERO);

    // The chaos workload shape at scale: 1–2 uniform files per task,
    // submitted in batches with a full drain (and a footprint sample)
    // after each.
    let mut rng = Pcg64::seeded(77);
    let batch = 10_000u64;
    let mut peak_bytes = core.table_bytes();
    let mut submitted = 0u64;
    let t0 = Instant::now();
    while submitted < tasks {
        let now = Micros::from_millis(submitted / batch);
        let end = (submitted + batch).min(tasks);
        while submitted < end {
            let dominant = FileId(rng.below(files) as u32);
            let mut tfiles = vec![dominant];
            if rng.below(100) < 35 {
                let second = FileId(rng.below(files) as u32);
                if second != dominant {
                    tfiles.push(second);
                }
            }
            let mut effs = core.on_arrival(
                Task {
                    id: TaskId(submitted),
                    files: tfiles,
                    compute: Micros::ZERO,
                    arrival: now,
                },
                0,
                0.0,
                now,
            );
            submitted += 1;
            q.extend(effs.drain(..));
            core.recycle_effects(effs);
        }
        scale_drain(&mut core, &mut q, now);
        peak_bytes = peak_bytes.max(core.table_bytes());
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    assert!(core.queue_is_empty(), "scale drive left tasks queued");

    let events = core.effect_events();
    let allocs = core.alloc_events();
    let allocs_per_event = allocs as f64 / events.max(1) as f64;
    println!(
        "    {tasks} tasks / {nodes} executors: {events} handler events in {elapsed:.2}s \
         ({:.2}M events/s), {allocs} pool misses ({allocs_per_event:.6}/event), \
         peak tables {peak_bytes} bytes",
        events as f64 / elapsed / 1e6
    );
    counters.push(("scale/events_per_sec".into(), events as f64 / elapsed));
    counters.push(("scale/allocs_per_event".into(), allocs_per_event));
    counters.push(("scale/peak_table_bytes".into(), peak_bytes as f64));

    // Timed steady-state case on the warm tables (the drive itself runs
    // once; repeating a 1M-task pump through `iter`'s warm-up/sampling
    // would dominate the whole bench binary).
    let mut id = tasks;
    let now = Micros::from_millis(tasks / batch + 1);
    b.iter("steady-state round trip (warm tables)", 1, || {
        let f = FileId(rng.below(files) as u32);
        let mut effs = core.on_arrival(
            Task {
                id: TaskId(id),
                files: vec![f],
                compute: Micros::ZERO,
                arrival: now,
            },
            0,
            0.0,
            now,
        );
        id += 1;
        q.extend(effs.drain(..));
        core.recycle_effects(effs);
        scale_drain(&mut core, &mut q, now);
    });
    let _ = b.write_csv();
    b
}

/// Whole-simulation event rate on a mid-sized workload (the §Perf
/// headline for the engine).
fn bench_whole_sim() -> Bench {
    let mut b = Bench::new("whole simulation (25K tasks, 64 nodes)")
        .samples(3)
        .min_sample_duration(std::time::Duration::from_millis(1));
    let mut cfg = ExperimentConfig::paper_fig(8).expect("preset");
    cfg.workload.num_tasks = 25_000;
    let mut events_per_s = 0.0;
    b.iter("fig08 @ 10% scale", 25_000, || {
        let r = datadiffusion::sim::run(&cfg);
        events_per_s = r.events_processed as f64 / r.sim_wall_s;
        black_box(r.summary.efficiency);
    });
    println!("  engine event rate: {:.2}M events/s", events_per_s / 1e6);
    let _ = b.write_csv();
    b
}
