//! §Perf hot-path microbenchmarks (DESIGN.md §8, EXPERIMENTS.md §Perf).
//!
//! Covers the three L3 hot paths: scheduler decisions, wait-queue window
//! ops, flow-network transfer churn, plus the whole-simulation event
//! rate. Run before/after every optimization:
//!
//!     cargo bench --bench perf_hotpath

use datadiffusion::cache::{CacheConfig, EvictionPolicy, ObjectCache};
use datadiffusion::config::ExperimentConfig;
use datadiffusion::coordinator::executor::ExecutorRegistry;
use datadiffusion::coordinator::queue::{Task, WaitQueue};
use datadiffusion::coordinator::scheduler::{DispatchPolicy, Scheduler, SchedulerConfig};
use datadiffusion::ids::{ExecutorId, FileId, TaskId};
use datadiffusion::index::LocationIndex;
use datadiffusion::sim::flow::FlowNet;
use datadiffusion::util::bench::{black_box, Bench};
use datadiffusion::util::prng::Pcg64;
use datadiffusion::util::time::Micros;

fn main() {
    datadiffusion::util::logger::init();
    bench_scheduler_decision();
    bench_waitqueue();
    bench_cache();
    bench_flownet();
    bench_whole_sim();
}

/// One phase-2 pickup on a warm 64-node cluster with a deep queue.
fn bench_scheduler_decision() {
    let mut b = Bench::new("scheduler pick_tasks (64 nodes, warm index)");
    for policy in [
        DispatchPolicy::FirstAvailable,
        DispatchPolicy::MaxComputeUtil,
        DispatchPolicy::GoodCacheCompute,
    ] {
        let mut reg = ExecutorRegistry::new();
        let mut index = LocationIndex::new();
        let mut rng = Pcg64::seeded(1);
        let execs: Vec<ExecutorId> =
            (0..64).map(|_| reg.register(2, Micros::ZERO)).collect();
        // Warm index: every file cached somewhere.
        for f in 0..10_000u32 {
            index.add(FileId(f), *rng.choose(&execs));
        }
        let mut queue = WaitQueue::new();
        for i in 0..50_000u64 {
            queue.push_back(Task {
                id: TaskId(i),
                files: vec![FileId(rng.below(10_000) as u32)],
                compute: Micros::ZERO,
                arrival: Micros::ZERO,
            });
        }
        let mut sched = Scheduler::new(SchedulerConfig {
            policy,
            ..SchedulerConfig::default()
        });
        let mut e = 0usize;
        b.iter(policy.name(), 1, || {
            e = (e + 1) % execs.len();
            let got = sched.pick_tasks(execs[e], 1, &mut queue, &reg, &index);
            // Re-queue so the bench is steady-state.
            for t in got {
                queue.push_back(t);
            }
        });
    }
    let _ = b.write_csv();
}

fn bench_waitqueue() {
    let mut b = Bench::new("wait-queue ops");
    let mut q = WaitQueue::new();
    for i in 0..100_000u64 {
        q.push_back(Task {
            id: TaskId(i),
            files: vec![FileId(i as u32)],
            compute: Micros::ZERO,
            arrival: Micros::ZERO,
        });
    }
    b.iter("push+pop", 1, || {
        let t = q.pop_front().expect("non-empty");
        q.push_back(t);
    });
    b.iter("window scan 3200", 3200, || {
        let n = q.window(3200).count();
        black_box(n);
    });
    let _ = b.write_csv();
}

fn bench_cache() {
    let mut b = Bench::new("object cache (LRU, 4GB, 10MB objects)");
    let mut cache = ObjectCache::new(CacheConfig {
        capacity_bytes: 4_000_000_000,
        policy: EvictionPolicy::Lru,
    });
    let mut rng = Pcg64::seeded(2);
    for f in 0..400u32 {
        cache.insert(FileId(f), 10_000_000, &mut rng);
    }
    b.iter("touch (hit)", 1, || {
        let f = FileId(rng.below(400) as u32);
        black_box(cache.touch(f));
    });
    b.iter("insert (evicting)", 1, || {
        let f = FileId(400 + rng.below(10_000) as u32);
        black_box(cache.insert(f, 10_000_000, &mut rng));
    });
    let _ = b.write_csv();
}

fn bench_flownet() {
    let mut b = Bench::new("flow network transfer churn");
    for concurrency in [16usize, 128] {
        let mut net = FlowNet::new();
        let gpfs = net.add_link(5.5e8);
        let nics: Vec<_> = (0..64).map(|_| net.add_link(1.25e8)).collect();
        let mut now = Micros::ZERO;
        let mut i = 0u64;
        // Prime with `concurrency` in-flight transfers.
        for _ in 0..concurrency {
            net.start(now, 10_000_000, &[gpfs, nics[(i % 64) as usize]], i);
            i += 1;
        }
        b.iter(&format!("start+complete @ {concurrency} concurrent"), 1, || {
            let t = net.next_completion().expect("in flight");
            now = t;
            net.pop_completion(t);
            net.start(now, 10_000_000, &[gpfs, nics[(i % 64) as usize]], i);
            i += 1;
        });
    }
    let _ = b.write_csv();
}

/// Whole-simulation event rate on a mid-sized workload (the §Perf
/// headline for the engine).
fn bench_whole_sim() {
    let mut b = Bench::new("whole simulation (25K tasks, 64 nodes)")
        .samples(3)
        .min_sample_duration(std::time::Duration::from_millis(1));
    let mut cfg = ExperimentConfig::paper_fig(8).expect("preset");
    cfg.workload.num_tasks = 25_000;
    let mut events_per_s = 0.0;
    b.iter("fig08 @ 10% scale", 25_000, || {
        let r = datadiffusion::sim::run(&cfg);
        events_per_s = r.events_processed as f64 / r.sim_wall_s;
        black_box(r.summary.efficiency);
    });
    println!("  engine event rate: {:.2}M events/s", events_per_s / 1e6);
    let _ = b.write_csv();
}
