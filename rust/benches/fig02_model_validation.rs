//! Figure 2 bench: abstract-model validation against the simulator
//! (§4.4 — paper: 5%/8% mean error, 29% worst case over 92 runs).
//!
//!     cargo bench --bench fig02_model_validation
//!
//! Env: `DD_SCALE` scales task counts (default 0.2 of paper scale).

use datadiffusion::experiments::fig02;

fn main() {
    datadiffusion::util::logger::init();
    let scale: f64 = std::env::var("DD_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);
    let t0 = std::time::Instant::now();
    let out = fig02::run(scale);
    for t in fig02::tables(&out) {
        t.print();
        let name = t.title.split(':').next().unwrap_or("fig02").replace(' ', "_");
        let _ = t.write_csv(&name);
    }
    let (mean_cpu, _, worst_cpu) = fig02::Fig02Output::stats(&out.cpu_sweep);
    let (mean_loc, _, worst_loc) = fig02::Fig02Output::stats(&out.locality_sweep);
    println!(
        "\nfig02 done in {:.1}s: cpu-sweep mean err {:.1}% (paper ~5%), \
         locality-sweep mean err {:.1}% (paper ~8%), worst {:.1}% (paper 29%)",
        t0.elapsed().as_secs_f64(),
        mean_cpu * 100.0,
        mean_loc * 100.0,
        worst_cpu.max(worst_loc) * 100.0
    );
}
