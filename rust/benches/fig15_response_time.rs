//! Figure 15 bench: average response time per experiment (§5.2.6 —
//! paper: 3.1 s best diffusion vs 1870 s worst GPFS, >500× apart).
//!
//!     cargo bench --bench fig15_response_time
//! Env: `DD_SCALE` (default 1.0).

use datadiffusion::experiments::{fig04_10, fig15};

fn main() {
    datadiffusion::util::logger::init();
    let scale: f64 = std::env::var("DD_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let results = fig04_10::scaled_run(scale);
    let t = fig15::table(&results);
    t.print();
    let _ = t.write_csv("fig15");
    println!(
        "\nshape: worst/best avg response = {:.0}× (paper: >500×)",
        fig15::best_worst_ratio(&results)
    );
}
