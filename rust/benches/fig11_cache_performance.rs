//! Figure 11 bench: cache hit/miss decomposition across the diffusion
//! experiments (§5.2.2 — paper: ~70% misses at 1 GB vs 4–6% at ≥1.5 GB).
//!
//!     cargo bench --bench fig11_cache_performance
//! Env: `DD_SCALE` (default 1.0).

use datadiffusion::experiments::{fig04_10, fig11};

fn main() {
    datadiffusion::util::logger::init();
    let scale: f64 = std::env::var("DD_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let results = fig04_10::scaled_run(scale);
    let t = fig11::table(&results);
    t.print();
    let _ = t.write_csv("fig11");
}
