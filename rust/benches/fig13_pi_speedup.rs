//! Figure 13 bench: performance index + speedup, including the static
//! 64-node comparison (§5.2.4 — paper: PI gain up to 34×; static PI 0.33
//! vs DRP 1.0 at equal speedup).
//!
//!     cargo bench --bench fig13_pi_speedup
//! Env: `DD_SCALE` (default 1.0).

use datadiffusion::experiments::{fig13, run_summary_experiment};

fn main() {
    datadiffusion::util::logger::init();
    let scale: f64 = std::env::var("DD_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let mut results = datadiffusion::experiments::fig04_10::scaled_run(scale);
    let mut static_cfg = fig13::static_best_config();
    static_cfg.workload.num_tasks =
        ((static_cfg.workload.num_tasks as f64 * scale) as u64).max(1_000);
    results.push(run_summary_experiment(&static_cfg));
    let t = fig13::table(&results);
    t.print();
    let _ = t.write_csv("fig13");

    let rows = fig13::rows(&results);
    let best_dd = rows
        .iter()
        .filter(|r| r.name.contains("gcc"))
        .map(|r| r.pi)
        .fold(0.0, f64::max);
    let fa = rows.first().expect("baseline");
    println!(
        "\nshape: PI(first-available) {:.3} vs best diffusion {:.3} → {:.0}× gain (paper: up to 34×)",
        fa.pi,
        best_dd,
        best_dd / fa.pi.max(1e-9)
    );
}
