//! Figure 12 bench: average + peak throughput split by data source
//! (§5.2.3 — paper: 4 Gb/s GPFS-only vs 5.3–13.9 Gb/s diffusion with
//! 100 Gb/s peaks; GPFS load drops to 0.4 Gb/s once the working set is
//! cached).
//!
//!     cargo bench --bench fig12_throughput
//! Env: `DD_SCALE` (default 1.0).

use datadiffusion::experiments::{fig04_10, fig12};

fn main() {
    datadiffusion::util::logger::init();
    let scale: f64 = std::env::var("DD_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let results = fig04_10::scaled_run(scale);
    let t = fig12::table(&results);
    t.print();
    let _ = t.write_csv("fig12");
}
