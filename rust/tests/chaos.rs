//! Chaos-harness acceptance gates.
//!
//! * a 40-run seeded sweep — all five dispatch policies × shards
//!   ∈ {1, 4} × four seeds each — must finish oracle-clean with at
//!   least one injected fault per run;
//! * re-running any seed must reproduce the identical fault schedule,
//!   tallies and state fingerprint;
//! * the §4.2 failure/replay path (`on_task_failed` → resubmit) keeps
//!   replica accounting exact and terminal states exactly-once under
//!   every policy, checked both directly against the core and through
//!   the harness with the failure rate cranked up;
//! * the oracle's self-test proves a deliberately broken invariant is
//!   caught and dumped with its seed, fault plan and trailing trace.

use datadiffusion::cache::CacheConfig;
use datadiffusion::chaos::{oracle_self_test, run_chaos, ChaosConfig, FaultKind};
use datadiffusion::coordinator::core::{
    CoordinatorCore, CoreConfig, Effect, FileSizes,
};
use datadiffusion::coordinator::provisioner::ProvisionerConfig;
use datadiffusion::coordinator::queue::Task;
use datadiffusion::coordinator::scheduler::{DispatchPolicy, SchedulerConfig};
use datadiffusion::ids::{FileId, TaskId};
use datadiffusion::util::prng::Pcg64;
use datadiffusion::util::time::Micros;
use std::collections::VecDeque;

#[test]
fn forty_run_sweep_is_oracle_clean_across_policies_and_shards() {
    let mut runs = 0u64;
    for policy in DispatchPolicy::ALL {
        for shards in [1usize, 4] {
            for _ in 0..4 {
                let mut cfg = ChaosConfig::quick(1_000 + runs);
                cfg.policy = policy;
                cfg.shards = shards;
                if shards > 1 {
                    cfg.nodes = 8; // every shard starts with real capacity
                }
                let r = run_chaos(&cfg);
                assert!(
                    r.faults_injected > 0,
                    "[{policy} K={shards} seed={}] injected no faults",
                    r.seed
                );
                assert!(
                    r.clean(),
                    "[{policy} K={shards} seed={}] not clean:\n{}",
                    r.seed,
                    r.dump.as_deref().unwrap_or("(stalled, no oracle dump)")
                );
                assert_eq!(
                    r.completed + r.failed,
                    r.events as u64,
                    "[{policy} K={shards} seed={}] terminal conservation",
                    r.seed
                );
                assert_eq!(
                    r.stale_rejected,
                    r.tally.count(FaultKind::CorruptCompletion),
                    "[{policy} K={shards} seed={}] every forged completion \
                     must bounce off the id tables, and nothing else may",
                    r.seed
                );
                runs += 1;
            }
        }
    }
    assert_eq!(runs, 40);
}

#[test]
fn reruns_reproduce_schedule_tallies_and_fingerprint() {
    for (seed, shards) in [(3u64, 1usize), (17, 4), (99, 1), (7_777, 4)] {
        let mut cfg = ChaosConfig::quick(seed);
        cfg.shards = shards;
        if shards > 1 {
            cfg.nodes = 8;
        }
        let a = run_chaos(&cfg);
        let b = run_chaos(&cfg);
        assert_eq!(a.plan, b.plan, "seed {seed}: fault schedule diverged");
        assert_eq!(a.tally, b.tally, "seed {seed}: tallies diverged");
        assert_eq!(
            a.fingerprint, b.fingerprint,
            "seed {seed}: state fingerprint diverged"
        );
        assert_eq!((a.completed, a.failed), (b.completed, b.failed));
    }
}

#[test]
fn harness_exercises_the_replay_path_under_every_policy() {
    // Crank the fault rate so partial transfers (→ on_task_failed →
    // resubmit) occur under every policy; clean() means the oracle
    // verified exactly-once terminals and replica accounting after
    // every one of those replays.
    for (i, policy) in DispatchPolicy::ALL.into_iter().enumerate() {
        let mut cfg = ChaosConfig::quick(50 + i as u64);
        cfg.policy = policy;
        cfg.fault_rate = 0.45;
        let r = run_chaos(&cfg);
        assert!(
            r.tally.count(FaultKind::PartialTransfer) > 0,
            "[{policy}] no partial transfers at rate {}: {}",
            cfg.fault_rate,
            r.tally
        );
        assert!(
            r.clean(),
            "[{policy}] replay stress not clean:\n{}",
            r.dump.as_deref().unwrap_or("(stalled)")
        );
        assert_eq!(r.completed + r.failed, r.events as u64, "[{policy}]");
    }
}

#[test]
fn heavy_tailed_scenario_sweep_is_oracle_clean() {
    // The ISSUE's scenario × chaos gate: the zipf-churn (heavy-tailed
    // popularity) stream through the fault schedule, all five policies
    // × K ∈ {1, 4}. Hot files concentrate replicas, so kills and
    // partial transfers hit the replica-accounting paths harder than
    // the uniform built-in stream does.
    use datadiffusion::config::ScenarioSpec;
    let mut runs = 0u64;
    for policy in DispatchPolicy::ALL {
        for shards in [1usize, 4] {
            let mut cfg = ChaosConfig::quick(9_000 + runs);
            cfg.policy = policy;
            cfg.shards = shards;
            if shards > 1 {
                cfg.nodes = 8;
            }
            cfg.scenario = Some(ScenarioSpec::preset("zipf-churn").expect("catalog"));
            let r = run_chaos(&cfg);
            assert!(
                r.clean(),
                "[{policy} K={shards} seed={}] scenario run not clean:\n{}",
                r.seed,
                r.dump.as_deref().unwrap_or("(stalled, no oracle dump)")
            );
            assert_eq!(
                r.completed + r.failed,
                r.events as u64,
                "[{policy} K={shards}] terminal conservation"
            );
            // Same seed + scenario reproduces bit-for-bit.
            let b = run_chaos(&cfg);
            assert_eq!(r.fingerprint, b.fingerprint, "[{policy} K={shards}]");
            runs += 1;
        }
    }
    assert_eq!(runs, 10);
}

#[test]
fn model_allocation_sweep_is_oracle_clean() {
    // The closed-loop controller (`--allocation model`) through the
    // fault schedule at K ∈ {1, 4}: the solved target shrinks and grows
    // while executors are killed mid-fetch/mid-compute, so this pins
    // (a) the controller never releases a mid-serve source — any such
    // release would break the oracle's replica accounting — and (b)
    // killed executors re-enter through Allocate/on_node_registered
    // until the fleet tracks the solved target again.
    use datadiffusion::coordinator::provisioner::AllocationPolicy;
    let mut runs = 0u64;
    for policy in DispatchPolicy::ALL {
        for shards in [1usize, 4] {
            let mut cfg = ChaosConfig::quick(21_000 + runs);
            cfg.policy = policy;
            cfg.shards = shards;
            cfg.allocation = AllocationPolicy::Model;
            if shards > 1 {
                cfg.nodes = 8;
            }
            let r = run_chaos(&cfg);
            assert!(
                r.faults_injected > 0,
                "[{policy} K={shards} seed={}] injected no faults",
                r.seed
            );
            assert!(
                r.clean(),
                "[{policy} K={shards} seed={}] model run not clean:\n{}",
                r.seed,
                r.dump.as_deref().unwrap_or("(stalled, no oracle dump)")
            );
            assert_eq!(
                r.completed + r.failed,
                r.events as u64,
                "[{policy} K={shards}] killed executors must re-enter the \
                 solved target until every task reaches a terminal state"
            );
            // Same seed reproduces bit-for-bit under the controller too.
            let b = run_chaos(&cfg);
            assert_eq!(r.fingerprint, b.fingerprint, "[{policy} K={shards}]");
            runs += 1;
        }
    }
    assert_eq!(runs, 10);
}

#[test]
fn model_allocation_fingerprint_default_is_unchanged() {
    // Adding the allocation knob must not move existing seeds: the
    // default config still runs mult:2 and reproduces itself.
    use datadiffusion::coordinator::provisioner::AllocationPolicy;
    let cfg = ChaosConfig::quick(3);
    assert_eq!(cfg.allocation, AllocationPolicy::Multiplicative(2.0));
    let a = run_chaos(&cfg);
    let b = run_chaos(&cfg);
    assert_eq!(a.fingerprint, b.fingerprint);
}

#[test]
fn self_test_dump_names_seed_plan_and_trace() {
    let dump = oracle_self_test();
    assert!(dump.contains("seed="), "no seed in dump:\n{dump}");
    assert!(dump.contains("fault plan"), "no plan in dump:\n{dump}");
    assert!(
        dump.contains("trailing event trace"),
        "no trace in dump:\n{dump}"
    );
    assert!(
        dump.contains("terminal state twice"),
        "broken invariant not named:\n{dump}"
    );
}

// ---- live engine under injected faults ---------------------------------

use datadiffusion::coordinator::provisioner::AllocationPolicy;
use datadiffusion::coordinator::shard::ShardedCoordinator;
use datadiffusion::live::{self, ComputeKind, LiveConfig, LiveFaults, LiveTask};
use std::path::PathBuf;
use std::time::Duration;

const LIVE_FILE_BYTES: u64 = 2048;

fn live_tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("dd-chaos-live-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

/// File ids grouped by home shard of a K-way router (the home hash is a
/// pure function of K, so a probe router predicts the live run's homes).
fn live_files_by_shard(k: usize, per_shard: usize) -> Vec<Vec<FileId>> {
    let probe = ShardedCoordinator::new(
        CoreConfig {
            scheduler: SchedulerConfig::default(),
            provisioner: ProvisionerConfig::default(),
            cache: CacheConfig::lru(1_000),
            max_nodes: k.max(1),
            slots_per_node: 1,
            file_sizes: FileSizes::Uniform(LIVE_FILE_BYTES),
        },
        k,
        Pcg64::seeded(1),
    );
    let mut by_shard: Vec<Vec<FileId>> = vec![Vec::new(); k];
    for raw in 0..4096u32 {
        let f = FileId(raw);
        let s = probe.shard_of_file(f);
        if by_shard[s].len() < per_shard {
            by_shard[s].push(f);
        }
        if by_shard.iter().all(|v| v.len() >= per_shard) {
            return by_shard;
        }
    }
    panic!("router hash left a shard empty over 4096 file ids");
}

#[test]
fn live_sweep_with_kill_and_partition_is_oracle_clean() {
    // The chaos fault menu through the *live* engine at K ∈ {1, 4}: a
    // worker thread killed mid-run (kill-mid-fetch: its in-flight work
    // is requeued via `on_executor_failed`) and, later, a shard
    // partition (cross-shard copies refused at assignment time). Every
    // run ends with the router's `check_integrity` oracle — a non-Ok
    // return here IS an oracle failure.
    for shards in [1usize, 4] {
        let by_shard = live_files_by_shard(shards, 2);
        let all_files: Vec<FileId> = by_shard.iter().flatten().copied().collect();
        let root = live_tmp(&format!("k{shards}"));
        let store = root.join("store");
        std::fs::create_dir_all(&store).expect("store dir");
        let name_of = |f: FileId| format!("f{}.bin", f.0);
        for &f in &all_files {
            std::fs::write(store.join(name_of(f)), vec![f.0 as u8; LIVE_FILE_BYTES as usize])
                .expect("dataset");
        }
        // Singles first (3× per file, seeding every shard), then — at
        // K=4 — one pair per shard whose second input is homed on the
        // next shard over, forcing cross-shard copies *after* the
        // partition trigger has fired.
        let mut tasks: Vec<LiveTask> = Vec::new();
        for _ in 0..3 {
            for &f in &all_files {
                tasks.push(LiveTask::single(name_of(f), f));
            }
        }
        if shards > 1 {
            for s in 0..shards {
                let g = by_shard[s][0];
                let foreign = by_shard[(s + 1) % shards][0];
                tasks.push(LiveTask {
                    file_name: name_of(g),
                    file: g,
                    extra: vec![(foreign, name_of(foreign))],
                });
            }
        }
        let total = tasks.len() as u64;

        let cfg = LiveConfig {
            // Two workers per shard: the kill always has an eligible
            // victim (no shard is ever emptied).
            initial_workers: 2 * shards,
            max_workers: 2 * shards,
            queue_tasks_per_worker: usize::MAX >> 8,
            allocation: AllocationPolicy::OneAtATime,
            policy: DispatchPolicy::GoodCacheCompute,
            cache: CacheConfig::lru(1 << 20),
            persistent_dir: store,
            cache_root: root.join("caches"),
            compute: ComputeKind::Sleep(Duration::from_millis(2)),
            seed: 4242 + shards as u64,
            idle_release_s: 0.0,
            shards,
            faults: LiveFaults {
                kill_worker_after: Some(5),
                partition_after: Some(10),
            },
        };
        let report = live::run(&cfg, &tasks)
            .unwrap_or_else(|e| panic!("[K={shards}] live chaos run failed its oracle: {e}"));

        assert_eq!(report.completed, total, "[K={shards}] tasks lost under faults");
        assert_eq!(report.failed, 0, "[K={shards}] no worker error was injected");
        assert!(
            report.shard.exec_failures >= 1,
            "[K={shards}] the kill fault was never enacted"
        );
        if shards > 1 {
            assert!(
                report.partition_fallbacks >= 1,
                "[K={shards}] no cross-shard copy was refused by the partition \
                 (cross_fetches={}, fallbacks={})",
                report.shard.cross_fetches,
                report.partition_fallbacks
            );
        } else {
            assert_eq!(report.shard.cross_fetches, 0, "[K=1] nothing to cross");
        }
        let _ = std::fs::remove_dir_all(&root);
    }
}

#[test]
fn live_release_deferral_probe_counts_both_sides() {
    // The scripted live probe: a cross-shard copy is in flight when the
    // idle-release tick fires, so the router must defer the serving
    // worker's release (`cross_release_deferrals`) and release it — plus
    // the requester — on a later tick (`workers_released`).
    let root = live_tmp("probe");
    let (released, deferrals) =
        live::scripted_cross_release_probe(&root).expect("scripted probe");
    assert!(
        deferrals >= 1,
        "release of a cross-serving worker was not deferred"
    );
    assert!(
        released >= 2,
        "idle workers were not released after the copy drained (got {released})"
    );
    let _ = std::fs::remove_dir_all(&root);
}

// ---- direct §4.2 replay coverage against the core ----------------------

fn replay_core(policy: DispatchPolicy) -> CoordinatorCore {
    CoordinatorCore::new(
        CoreConfig {
            scheduler: SchedulerConfig {
                policy,
                ..SchedulerConfig::default()
            },
            provisioner: ProvisionerConfig::default(),
            cache: CacheConfig::lru(1_000),
            max_nodes: 4,
            slots_per_node: 1,
            file_sizes: FileSizes::Uniform(10),
        },
        Pcg64::seeded(42),
    )
}

fn mk_task(id: u64, files: &[u32], arrival: Micros) -> Task {
    Task {
        id: TaskId(id),
        files: files.iter().map(|&f| FileId(f)).collect(),
        compute: Micros::from_millis(1),
        arrival,
    }
}

/// Synchronous mini-pump: enact effects depth-first, failing the first
/// fetch of `fail_task` once and resubmitting it per §4.2. Returns the
/// number of Compute completions fed back.
fn pump_with_one_failure(
    c: &mut CoordinatorCore,
    effects: Vec<Effect>,
    fail_task: TaskId,
    failed_once: &mut bool,
    now: Micros,
) -> u64 {
    let mut done = 0u64;
    let mut q: VecDeque<Effect> = effects.into();
    while let Some(eff) = q.pop_front() {
        match eff {
            Effect::Notify(e) => q.extend(c.on_pickup(e, now)),
            Effect::Fetch(plan) => {
                if plan.task_id == fail_task && !*failed_once {
                    *failed_once = true;
                    let files: Vec<u32> = vec![plan.file.0];
                    q.extend(c.on_task_failed(plan.task_id, now));
                    q.extend(c.on_arrival(mk_task(plan.task_id.0, &files, now), 0, 0.0, now));
                } else {
                    q.extend(c.on_fetch_done(plan.task_id, now, None));
                }
            }
            Effect::Compute { task_id, .. } => {
                done += 1;
                q.extend(c.on_compute_done(task_id, now, now));
            }
            Effect::Allocate(_) | Effect::Release(_) => {}
        }
    }
    done
}

#[test]
fn task_failure_replay_is_exactly_once_for_every_policy() {
    for policy in DispatchPolicy::ALL {
        let mut c = replay_core(policy);
        c.register_node(Micros::ZERO);
        c.register_node(Micros::ZERO);

        let mut failed_once = false;
        let mut done = 0u64;
        let mut effects = c.on_arrival(mk_task(0, &[5], Micros::ZERO), 0, 0.0, Micros::ZERO);
        // Drain with the kick safety net (max-cache-hit may decline the
        // first notify); bounded so a regression stalls loudly.
        for round in 0u64.. {
            done += pump_with_one_failure(
                &mut c,
                effects,
                TaskId(0),
                &mut failed_once,
                Micros::from_millis(round),
            );
            if c.queue_is_empty() {
                break;
            }
            effects = c.kick();
            assert!(
                round < 16,
                "[{policy}] replay never drained (round {round})"
            );
        }
        assert!(failed_once, "[{policy}] the fetch was never failed");
        assert_eq!(done, 1, "[{policy}] task must reach Compute exactly once");
        assert_eq!(
            c.rec.tasks_done(),
            1,
            "[{policy}] exactly one recorded completion"
        );
        c.check_integrity()
            .unwrap_or_else(|m| panic!("[{policy}] replica accounting diverged: {m}"));
        assert!(c.queue_is_empty(), "[{policy}] queue not drained");
        assert_eq!(c.free_count(), 2, "[{policy}] slot not freed");
    }
}
