//! Cross-driver parity: the discrete-event simulator and the live
//! thread-pool engine drive the **same** `CoordinatorCore`, so on a
//! deterministic workload they must replay the *identical* decision
//! sequence — same tasks dispatched in the same order, same
//! HitLocal/HitGlobal/Miss tallies out of the shared recorder.
//!
//! Determinism setup:
//!
//! * **one executor with one slot** on both sides (sim: 1 static node ×
//!   1 CPU; live: 1 worker, max 1), so pickups serialize and wall-clock
//!   jitter cannot reorder decisions;
//! * **batch arrivals**: the whole task stream is queued before the
//!   first pickup fires (the sim's dispatcher service latency outruns
//!   same-instant arrivals; the live driver queues notifications FIFO
//!   and delivers them after submission);
//! * **LRU caches, single executor**: `resolve_access` draws no
//!   randomness (no peers to pick, no random eviction), so the two
//!   engines' different PRNG streams cannot diverge the cache state;
//! * the file sequence comes from one `workload::generate` call — the
//!   sim consumes it directly, the live side materializes the same
//!   sequence as real files in a temp persistent store.
//!
//! Policies under test dispatch unconditionally on a single free
//! executor (good-cache-compute in mcu mode, max-compute-util,
//! first-available), so neither driver's progress safety net fires and
//! the traces are pure scheduler decisions.

use datadiffusion::cache::EvictionPolicy;
use datadiffusion::config::{ArrivalSpec, ExperimentConfig};
use datadiffusion::coordinator::provisioner::{AllocationPolicy, ProvisionerConfig};
use datadiffusion::coordinator::scheduler::DispatchPolicy;
use datadiffusion::live::{self, ComputeKind, LiveConfig, LiveTask};
use datadiffusion::sim;
use datadiffusion::workload;
use std::path::PathBuf;
use std::time::Duration;

const NUM_TASKS: u64 = 240;
const NUM_FILES: u32 = 40;
const FILE_BYTES: u64 = 1024;
/// 12 of 40 files fit per cache: steady eviction churn on both sides.
const CACHE_BYTES: u64 = 12 * FILE_BYTES;

fn sim_cfg(policy: DispatchPolicy) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("core-parity-{policy}");
    cfg.seed = 7;
    cfg.cluster.max_nodes = 1;
    cfg.cluster.cpus_per_node = 1;
    cfg.workload.num_tasks = NUM_TASKS;
    cfg.workload.num_files = NUM_FILES;
    cfg.workload.file_size_bytes = FILE_BYTES;
    cfg.workload.arrival = ArrivalSpec::Batch;
    cfg.scheduler.policy = policy;
    cfg.cache.capacity_bytes = CACHE_BYTES;
    cfg.cache.policy = EvictionPolicy::Lru;
    cfg.provisioner = ProvisionerConfig::static_nodes(1);
    cfg
}

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("dd-core-parity-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

#[test]
fn sim_and_live_drivers_replay_identical_decisions() {
    for policy in [
        DispatchPolicy::GoodCacheCompute,
        DispatchPolicy::MaxComputeUtil,
        DispatchPolicy::FirstAvailable,
    ] {
        let cfg = sim_cfg(policy);

        // --- sim driver over the shared core.
        let sim_result = sim::run(&cfg);
        assert_eq!(
            sim_result.summary.tasks_completed, NUM_TASKS,
            "[{policy}] sim incomplete"
        );

        // --- live driver over the same core, same file sequence.
        let wl = workload::generate(&cfg.workload, cfg.seed);
        let root = tmp(&format!("{policy}"));
        let store = root.join("store");
        std::fs::create_dir_all(&store).expect("store dir");
        let mut tasks: Vec<LiveTask> = Vec::with_capacity(wl.tasks.len());
        for spec in &wl.tasks {
            // Legacy workloads are single-input; the live harness reads
            // the task's dominant file.
            let file = spec.inputs[0];
            let name = format!("f{}.bin", file.0);
            tasks.push(LiveTask::single(name, file));
        }
        for f in 0..NUM_FILES {
            // Exactly file_size_bytes on disk so the live cache model
            // admits/evicts in lockstep with the sim's uniform sizes.
            let path = store.join(format!("f{f}.bin"));
            std::fs::write(&path, vec![f as u8; FILE_BYTES as usize]).expect("dataset");
        }
        let live_cfg = LiveConfig {
            initial_workers: 1,
            max_workers: 1,
            queue_tasks_per_worker: usize::MAX >> 8, // never grow
            allocation: AllocationPolicy::OneAtATime,
            policy,
            cache: cfg.cache,
            persistent_dir: store,
            cache_root: root.join("caches"),
            compute: ComputeKind::Sleep(Duration::ZERO),
            seed: 999, // different stream on purpose: must not matter
            idle_release_s: 0.0,
            shards: 1,
            faults: live::LiveFaults::default(),
        };
        let report = live::run(&live_cfg, &tasks).expect("live run");
        assert_eq!(report.completed, NUM_TASKS, "[{policy}] live incomplete");
        assert_eq!(report.failed, 0, "[{policy}] live failures");

        // --- identical decision traces and access tallies.
        assert_eq!(
            sim_result.dispatch_order.len() as u64,
            NUM_TASKS,
            "[{policy}] sim dispatched a task more than once"
        );
        assert_eq!(
            sim_result.dispatch_order, report.dispatch_order,
            "[{policy}] drivers diverged on dispatch order"
        );
        let live_counts = (report.hits_local, report.hits_global, report.misses);
        assert_eq!(
            sim_result.access_counts, live_counts,
            "[{policy}] drivers diverged on access tallies"
        );
        // Single executor ⇒ no peer to hit; sanity-check the split.
        assert_eq!(live_counts.1, 0, "[{policy}] global hit without a peer");
        if policy == DispatchPolicy::FirstAvailable {
            assert_eq!(live_counts, (0, 0, NUM_TASKS), "[{policy}] fa never caches");
        } else {
            assert!(
                live_counts.0 > 0,
                "[{policy}] parity is vacuous without cache hits"
            );
        }
        let _ = std::fs::remove_dir_all(&root);
    }
}
