//! Integration tests: whole-system behaviour across modules.
//!
//! These run scaled-down versions of the paper's experiments (the full
//! 250K-task runs live in the benches) and assert the qualitative
//! properties the paper demonstrates, plus engineering invariants
//! (determinism, conservation, config round-trips).

use datadiffusion::config::{AccessSpec, ArrivalSpec, ExperimentConfig};
use datadiffusion::coordinator::provisioner::ProvisionerConfig;
use datadiffusion::coordinator::scheduler::DispatchPolicy;
use datadiffusion::experiments::{fig02, fig03, registry, throughput_split};
use datadiffusion::sim;
use datadiffusion::util::units::{GB, MB};

/// A 10%-scale version of the paper's §5.2 workload.
fn scaled_paper_cfg(fig: u32, scale: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_fig(fig).expect("preset");
    cfg.workload.num_tasks /= scale;
    cfg
}

#[test]
fn determinism_full_stack() {
    let cfg = scaled_paper_cfg(8, 25);
    let a = sim::run(&cfg);
    let b = sim::run(&cfg);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(
        a.summary.workload_execution_time_s,
        b.summary.workload_execution_time_s
    );
    assert_eq!(a.summary.hit_local_rate, b.summary.hit_local_rate);
    assert_eq!(a.summary.cpu_time_hours, b.summary.cpu_time_hours);
    // Different seed ⇒ different micro-behaviour (but tasks all finish).
    let mut cfg2 = cfg.clone();
    cfg2.seed += 1;
    let c = sim::run(&cfg2);
    assert_eq!(c.summary.tasks_completed, a.summary.tasks_completed);
    assert_ne!(a.events_processed, c.events_processed);
}

#[test]
fn task_conservation_across_policies() {
    for policy in DispatchPolicy::ALL {
        let mut cfg = scaled_paper_cfg(8, 50);
        cfg.scheduler.policy = policy;
        let r = sim::run(&cfg);
        assert_eq!(
            r.summary.tasks_completed, cfg.workload.num_tasks,
            "policy {policy} lost tasks"
        );
        // Every task reads exactly one file: accesses sum to tasks.
        let rates =
            r.summary.hit_local_rate + r.summary.hit_global_rate + r.summary.miss_rate;
        assert!((rates - 1.0).abs() < 1e-9);
        // Bytes moved = tasks × file size.
        let total: u64 = r
            .ts
            .buckets()
            .iter()
            .map(|b| b.bytes_total())
            .sum();
        assert_eq!(total, cfg.workload.num_tasks * cfg.workload.file_size_bytes);
    }
}

#[test]
fn diffusion_beats_gpfs_baseline() {
    // The paper's headline: data diffusion crushes first-available on
    // both execution time and response time once caches hold the
    // working set. The scaled workload must actually exceed the GPFS
    // capacity (~55 tasks/s at 10 MB), so ramp fast to 400/s.
    let mk = |fig: u32| {
        let mut cfg = scaled_paper_cfg(fig, 20);
        cfg.workload.arrival = ArrivalSpec::IncreasingRate {
            initial: 10.0,
            factor: 1.6,
            interval_s: 15.0,
            max_rate: 400.0,
        };
        cfg
    };
    let fa = sim::run(&mk(4));
    let gcc = sim::run(&mk(8));
    assert!(
        gcc.summary.workload_execution_time_s < fa.summary.workload_execution_time_s,
        "no speedup: {} vs {}",
        gcc.summary.workload_execution_time_s,
        fa.summary.workload_execution_time_s
    );
    assert!(
        gcc.summary.avg_response_time_s * 2.0 < fa.summary.avg_response_time_s,
        "response gap too small: {} vs {}",
        gcc.summary.avg_response_time_s,
        fa.summary.avg_response_time_s
    );
    // GPFS-only throughput is pinned at the GPFS cap; diffusion exceeds it.
    assert!(gcc.summary.peak_throughput_gbps > fa.summary.peak_throughput_gbps * 2.0);
}

#[test]
fn cache_size_scaling_shape() {
    // Fig 5→8 shape at 10% scale: bigger caches, faster runs (weakly).
    let wets: Vec<f64> = [5u32, 6, 7, 8]
        .iter()
        .map(|&f| sim::run(&scaled_paper_cfg(f, 10)).summary.workload_execution_time_s)
        .collect();
    assert!(wets[1] <= wets[0] * 1.02, "1.5GB {} vs 1GB {}", wets[1], wets[0]);
    assert!(wets[2] <= wets[1] * 1.02, "2GB {} vs 1.5GB {}", wets[2], wets[1]);
    assert!(
        (wets[3] - wets[2]).abs() / wets[2] < 0.15,
        "4GB ≈ 2GB expected: {} vs {}",
        wets[3],
        wets[2]
    );
}

#[test]
fn static_provisioning_burns_more_cpu_hours() {
    // Fig 13's PI story at reduced scale.
    let dyn_r = sim::run(&scaled_paper_cfg(8, 10));
    let mut static_cfg = scaled_paper_cfg(8, 10);
    static_cfg.provisioner = ProvisionerConfig::static_nodes(64);
    let static_r = sim::run(&static_cfg);
    // Similar speed…
    let ratio = static_r.summary.workload_execution_time_s
        / dyn_r.summary.workload_execution_time_s;
    assert!(ratio < 1.1, "static should not be slower: {ratio}");
    // …but more CPU time than DRP.
    assert!(
        static_r.summary.cpu_time_hours > dyn_r.summary.cpu_time_hours * 1.3,
        "static {} !≫ dynamic {}",
        static_r.summary.cpu_time_hours,
        dyn_r.summary.cpu_time_hours
    );
}

#[test]
fn gpfs_never_exceeds_capacity_and_caches_offload_it() {
    let mut cfg = scaled_paper_cfg(8, 10);
    // Scale the dataset with the task count so accesses-per-file stays
    // at the paper's 25 (otherwise cold misses dominate at 10% scale).
    cfg.workload.num_files /= 10;
    let r = sim::run(&cfg);
    // Bytes are credited at transfer completion, so single seconds can
    // burst; the cap must hold on a 10-second moving window.
    let cap = cfg.cluster.gpfs_gbps * 1.10;
    let buckets = r.ts.buckets();
    for (sec, win) in buckets.windows(10).enumerate() {
        let bytes: u64 = win.iter().map(|b| b.bytes_gpfs).sum();
        let gbps = datadiffusion::util::units::bps_to_gbps(bytes as f64 / 10.0);
        assert!(gbps <= cap, "window @{sec}s: GPFS {gbps} Gb/s over cap");
    }
    let split = throughput_split(&r);
    assert!(
        split.local_gbps > split.gpfs_gbps,
        "diffusion should serve most bytes locally: {split:?}"
    );
}

#[test]
fn model_tracks_simulator_within_tolerance() {
    // Fig 2 mini-validation: the paper reports 5-8% mean error with a
    // 29% worst case; at our reduced scale allow a generous 35% bound
    // per point and 15% on the mean.
    let points = [
        fig02::run_point(8, 2.0, 3_000),
        fig02::run_point(32, 5.0, 3_000),
        fig02::run_point(64, 10.0, 3_000),
        fig02::run_point(128, 30.0, 3_000),
    ];
    let mean: f64 =
        points.iter().map(|p| p.error).sum::<f64>() / points.len() as f64;
    for p in &points {
        assert!(
            p.error < 0.35,
            "point cpus={} loc={} error {:.1}%",
            p.cpus,
            p.locality,
            p.error * 100.0
        );
    }
    assert!(mean < 0.20, "mean model error {:.1}%", mean * 100.0);
}

#[test]
fn figure_registry_parallel_matches_serial() {
    // The `figures --jobs N` guarantee: merged tables are byte-identical
    // for any job count. Deterministic figures only (Figure 3 reports
    // measured wall-clock throughput and is excluded by contract).
    let ids = ["fig11", "fig12", "fig15"];
    let render = |jobs: usize| -> Vec<String> {
        registry::run_selected(&ids, 0.004, jobs) // 1K-task floor per run
            .iter()
            .flat_map(|o| {
                assert!(o.deterministic, "{} must be deterministic", o.id);
                o.tables.iter().map(|t| t.render())
            })
            .collect()
    };
    let serial = render(1);
    let parallel = render(4);
    assert_eq!(serial, parallel, "parallel tables diverged from serial");
    assert_eq!(serial.len(), 3);
}

#[test]
fn figure_registry_check_passes_on_quick_sweeps() {
    // The figures-smoke gate logic over a real (tiny) run.
    let outs = registry::run_selected(&["fig13", "sweep-dispatch"], 0.004, 4);
    registry::check_outputs(&outs).expect("quick figures must be NaN-free and non-empty");
    // fig13 renders the seven paper runs + the static row.
    assert_eq!(outs[0].tables[0].rows.len(), 8);
}

#[test]
fn scheduler_microbench_dispatches_everything() {
    // Fig 3 at 2% scale, all five policies.
    for r in fig03::run(5_000, 1_000, 8) {
        assert_eq!(r.tasks, 5_000, "{}", r.policy);
        assert!(r.decisions_per_sec > 1_000.0, "{}: {}", r.policy, r.decisions_per_sec);
    }
}

#[test]
fn locality_workloads_cache_better() {
    let mk = |access: AccessSpec| {
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.max_nodes = 8;
        cfg.workload.num_tasks = 5_000;
        cfg.workload.num_files = 2_000;
        cfg.workload.file_size_bytes = 10 * MB;
        cfg.workload.arrival = ArrivalSpec::Constant(100.0);
        cfg.workload.access = access;
        cfg.cache.capacity_bytes = GB;
        sim::run(&cfg)
    };
    let uniform = mk(AccessSpec::Uniform);
    let zipf = mk(AccessSpec::Zipf(1.1));
    let local = mk(AccessSpec::Locality(10.0));
    assert!(
        zipf.summary.hit_local_rate > uniform.summary.hit_local_rate,
        "zipf {} !> uniform {}",
        zipf.summary.hit_local_rate,
        uniform.summary.hit_local_rate
    );
    assert!(
        local.summary.hit_local_rate > uniform.summary.hit_local_rate,
        "locality {} !> uniform {}",
        local.summary.hit_local_rate,
        uniform.summary.hit_local_rate
    );
}

#[test]
fn eviction_policy_ablation_runs_all_policies() {
    use datadiffusion::cache::EvictionPolicy;
    for ev in [
        EvictionPolicy::Lru,
        EvictionPolicy::Lfu,
        EvictionPolicy::Fifo,
        EvictionPolicy::Random,
    ] {
        let mut cfg = scaled_paper_cfg(5, 50);
        cfg.cache.policy = ev;
        let r = sim::run(&cfg);
        assert_eq!(r.summary.tasks_completed, cfg.workload.num_tasks, "{ev:?}");
    }
}

#[test]
fn config_file_round_trip_drives_simulation() {
    let toml = r#"
        name = "integration-toml"
        seed = 9
        [cluster]
        max_nodes = 4
        [workload]
        num_tasks = 1500
        num_files = 100
        file_size_mb = 5.0
        arrival = "constant"
        arrival_rate = 80.0
        [scheduler]
        policy = "good-cache-compute"
        [cache]
        capacity_gb = 1.0
    "#;
    let cfg = ExperimentConfig::from_toml(toml).expect("parse");
    let r = sim::run(&cfg);
    assert_eq!(r.summary.tasks_completed, 1500);
    assert_eq!(r.name, "integration-toml");
}

#[test]
fn shard_snapshot_round_trip_is_bit_identical() {
    use datadiffusion::experiments::shardio;
    use datadiffusion::metrics::Recorder;

    // Two K=4 runs under different policies, emitted as one snapshot
    // envelope per shard and recombined from the files.
    let mut cfgs = Vec::new();
    for (name, policy) in [
        ("rt-gcc", DispatchPolicy::GoodCacheCompute),
        ("rt-fa", DispatchPolicy::FirstAvailable),
    ] {
        let mut cfg = scaled_paper_cfg(8, 50);
        cfg.name = name.into();
        cfg.scheduler.policy = policy;
        cfg.cluster.shards = 4;
        cfgs.push(cfg);
    }
    let dir = std::env::temp_dir().join(format!("dd-integ-rt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let paths = shardio::emit_shards(&cfgs, &dir).expect("emit");
    assert_eq!(paths.len(), 8, "two runs × four shards");
    let merged = shardio::merge_dir(&dir).expect("merge");
    assert_eq!(merged.len(), 2);

    for m in &merged {
        let cfg = cfgs.iter().find(|c| c.name == m.name).expect("run name");
        assert_eq!(m.shards, 4);
        // The in-process reference: same run, shard recorders absorbed
        // directly without ever leaving the process.
        let (reference, shard_recs) = sim::run_with_shard_recorders(cfg);
        let mut inproc = Recorder::new();
        for r in shard_recs {
            inproc.absorb(r);
        }
        assert_eq!(m.recorder.access_counts(), inproc.access_counts(), "{}", m.name);
        assert_eq!(m.recorder.tasks_done(), inproc.tasks_done(), "{}", m.name);
        // The summary is all f64s; Debug formatting shows every bit that
        // matters, so string equality pins bit-identity end to end.
        let s = m.recorder.summarize(m.ideal_wet_s);
        assert_eq!(
            format!("{s:?}"),
            format!("{:?}", reference.summary),
            "{}: file-merged summary diverged from the in-process run",
            m.name
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_shard_snapshots_fail_typed_not_panic() {
    use datadiffusion::config::ConfigError;
    use datadiffusion::experiments::shardio;
    use datadiffusion::Error;

    let mut cfg = scaled_paper_cfg(8, 100);
    cfg.name = "rt-corrupt".into();
    cfg.cluster.shards = 2;
    let dir = std::env::temp_dir().join(format!("dd-integ-corrupt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let paths = shardio::emit_shards(std::slice::from_ref(&cfg), &dir).expect("emit");
    let pristine = std::fs::read_to_string(&paths[0]).expect("read envelope");

    // Truncated mid-stream: the `end` record never arrives.
    std::fs::write(&paths[0], &pristine[..pristine.len() / 2]).expect("truncate");
    let err = shardio::merge_dir(&dir).expect_err("truncated envelope must fail");
    assert!(
        matches!(err, Error::Config(_)),
        "truncation must surface as a typed config error, got {err:?}"
    );

    // Corrupted record: a line that is not valid envelope JSON.
    let garbled = pristine.replacen("\"kind\":\"meta\"", "\"kind\":\"mete\"", 1);
    std::fs::write(&paths[0], garbled).expect("garble");
    let err = shardio::merge_dir(&dir).expect_err("garbled envelope must fail");
    assert!(
        matches!(
            err,
            Error::Config(ConfigError::InvalidValue { .. })
                | Error::Config(ConfigError::MissingKey { .. })
        ),
        "corruption must surface as a typed config error, got {err:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn failure_free_but_stressed_provisioning_cycles() {
    // Bursty arrivals with aggressive release: nodes should be released
    // between bursts and re-acquired, and everything still completes.
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.max_nodes = 16;
    cfg.cluster.gram_latency_s = (2.0, 4.0);
    cfg.workload.num_tasks = 4_000;
    cfg.workload.num_files = 200;
    cfg.workload.file_size_bytes = 5 * MB;
    // Slow constant arrival with long tail → idle periods.
    cfg.workload.arrival = ArrivalSpec::Constant(20.0);
    cfg.provisioner.idle_release_s = 5.0;
    let r = sim::run(&cfg);
    assert_eq!(r.summary.tasks_completed, 4_000);
    // Fleet should have both grown and (possibly) contracted; at minimum
    // it never exceeded the cap.
    let max_nodes = r.ts.buckets().iter().map(|b| b.nodes).max().unwrap();
    assert!(max_nodes <= 16);
}
