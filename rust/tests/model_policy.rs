//! Acceptance gates for the model-predictive provisioner
//! (`--allocation model`).
//!
//! * **Offline ↔ online consistency**: the online solver's optimum over
//!   fig02's validation points must equal a brute-force argmax over the
//!   offline `model::predict` — fig02 is the golden oracle for the
//!   controller, not a dead table;
//! * **Scenario divergence** (ROADMAP item 2's measurable claim): on
//!   the two bursty families (zipf-churn, diurnal) the `model` policy
//!   achieves a performance index at least as high as the best static
//!   policy while holding strictly fewer node-seconds than `all`, with
//!   seeds pinned and the workload fingerprint stable per family;
//! * **End-to-end**: `--allocation model` completes a sharded scenario
//!   run (K = 4) deterministically.

use datadiffusion::config::ScenarioSpec;
use datadiffusion::coordinator::model::{solve, SolveInputs};
use datadiffusion::coordinator::provisioner::AllocationPolicy;
use datadiffusion::experiments::registry::run_configs;
use datadiffusion::experiments::sweeps::{node_seconds, ALLOCATION_POLICIES};
use datadiffusion::experiments::{fig02, scenarios};
use datadiffusion::model::{self, ModelInputs};
use datadiffusion::workload;

/// Brute-force best-PI fleet over the *offline* model: scan every
/// admissible node count, call `model::predict` directly, and keep the
/// smallest fleet maximizing `1 / (n · W²)` — the §3 performance-index
/// score with the constant workload factors cancelled.
fn offline_best_pi(offline: &ModelInputs, cpus_per_node: usize, max_nodes: usize) -> usize {
    let mut best_n = 0usize;
    let mut best = f64::NEG_INFINITY;
    for n in 1..=max_nodes {
        let m = ModelInputs {
            cpus: (n * cpus_per_node) as f64,
            ..*offline
        };
        let w = model::predict(&m).w.max(1e-12);
        let score = 1.0 / (n as f64 * w * w);
        if score > best {
            best = score;
            best_n = n;
        }
    }
    best_n
}

#[test]
fn solver_optima_match_the_offline_models_best_pi_entries() {
    // The fig02 grid: the CPU panel's localities × a batch workload,
    // plus finite arrival rates layered on top so the online knee
    // (arrival saturation) is exercised, not just the batch limit.
    let max_nodes = 64usize;
    for &locality in &[1.0, 1.38, 5.0, 30.0] {
        for &tasks in &[2_000u64, 23_000] {
            let cfg = fig02::validation_config(128, locality, tasks);
            let offline = ModelInputs::from_config(&cfg);
            for &rate in &[f64::INFINITY, 5.0, 50.0, 500.0] {
                let offline = ModelInputs {
                    arrival_rate: rate,
                    ..offline
                };
                let inp = SolveInputs {
                    queue_len: offline.num_tasks as usize,
                    arrival_rate: offline.arrival_rate,
                    mu_s: offline.mu_s,
                    overhead_s: offline.overhead_s,
                    object_bytes: offline.object_bytes,
                    p_miss: offline.p_miss,
                    p_local: offline.p_local,
                    persistent_bps: offline.persistent_bps,
                    transient_bps: offline.transient_bps,
                    cpus_per_node: cfg.cluster.cpus_per_node as u32,
                    min_nodes: 1,
                    max_nodes,
                };
                let solved = solve(&inp);
                let oracle = offline_best_pi(&offline, cfg.cluster.cpus_per_node, max_nodes);
                assert_eq!(
                    solved.nodes, oracle,
                    "locality {locality}, {tasks} tasks, rate {rate}: \
                     online solve diverged from the offline best-PI entry"
                );
                // And the solver's reported makespan is the offline
                // model's prediction at that fleet, bit for bit.
                let m = ModelInputs {
                    cpus: (oracle * cfg.cluster.cpus_per_node) as f64,
                    ..offline
                };
                assert_eq!(
                    solved.w.to_bits(),
                    model::predict(&m).w.to_bits(),
                    "solver must report the offline model's W verbatim"
                );
            }
        }
    }
}

/// Run one scenario family through all five allocation policies at
/// smoke scale (seed 42 via `scenario_config`); returns the results in
/// [`ALLOCATION_POLICIES`] order.
fn family_results(family: &str) -> Vec<datadiffusion::sim::RunResult> {
    let spec = ScenarioSpec::preset(family).expect("catalog name");
    let cfgs: Vec<_> = ALLOCATION_POLICIES
        .iter()
        .map(|(label, policy)| {
            let mut cfg = scenarios::scenario_config(&spec, 0.02, 1);
            cfg.name = format!("divergence-{family}-{label}");
            cfg.provisioner.allocation = *policy;
            cfg
        })
        .collect();
    // The task stream is a property of the workload config alone: every
    // policy consumes the identical pinned stream (the family's golden
    // fingerprint), so the runs differ only in provisioning.
    let fp = workload::generate(&cfgs[0].workload, cfgs[0].seed).fingerprint();
    for cfg in &cfgs {
        assert_eq!(
            workload::generate(&cfg.workload, cfg.seed).fingerprint(),
            fp,
            "{family}: the pinned stream drifted across policy configs"
        );
    }
    run_configs(cfgs, 2)
}

#[test]
fn model_matches_best_static_pi_with_fewer_node_seconds_on_bursty_families() {
    for family in ["zipf-churn", "diurnal"] {
        let results = family_results(family);
        assert_eq!(results.len(), ALLOCATION_POLICIES.len());
        let expected = results[0].summary.tasks_completed;
        for (r, (label, _)) in results.iter().zip(ALLOCATION_POLICIES.iter()) {
            assert_eq!(
                r.summary.tasks_completed, expected,
                "{family}/{label}: incomplete run"
            );
        }
        // PI against the family's own `one` baseline (results[0]).
        let base_wet = results[0].summary.workload_execution_time_s;
        let pi: Vec<f64> = results
            .iter()
            .map(|r| r.summary.performance_index_raw(base_wet))
            .collect();
        let best_static = pi[..4].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let model_pi = pi[4];
        assert!(
            model_pi >= best_static,
            "{family}: model PI {model_pi:.4} below best static {best_static:.4} \
             (per-policy PI: {pi:?})"
        );
        // The controller must hold strictly fewer node-seconds than the
        // allocate-everything policy (index 3 = `all`).
        let ns_all = node_seconds(&results[3]);
        let ns_model = node_seconds(&results[4]);
        assert!(
            ns_model < ns_all,
            "{family}: model node-seconds {ns_model} not below all's {ns_all}"
        );
    }
}

#[test]
fn sharded_model_scenario_run_is_deterministic_end_to_end() {
    let spec = ScenarioSpec::preset("diurnal").expect("catalog name");
    let mut cfg = scenarios::scenario_config(&spec, 0.02, 4);
    cfg.name = "model-k4-diurnal".into();
    cfg.provisioner.allocation = AllocationPolicy::Model;
    let expected = workload::generate(&cfg.workload, cfg.seed).tasks.len() as u64;
    let a = datadiffusion::sim::run(&cfg);
    let b = datadiffusion::sim::run(&cfg);
    assert_eq!(a.summary.tasks_completed, expected, "sharded model run incomplete");
    assert_eq!(a.dispatch_order, b.dispatch_order, "rerun diverged");
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.shard, b.shard, "router counters diverged across reruns");
}
