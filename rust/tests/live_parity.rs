//! Live-vs-core parity battery for the sharded live engine (PR 10).
//!
//! Two pins hold the live engine to the coordinator's decision stream:
//!
//! 1. **K=1 bit-identity** — a real `live::run` (worker threads, cache
//!    directories, filesystem copies) over a single shard must replay
//!    the *bare* [`CoordinatorCore`]'s dispatch order and access
//!    tallies exactly, where the reference is a synchronous in-process
//!    driver that enacts effects the same way the live driver does
//!    (FIFO notify queue, fetch → `on_fetch_done(Some(observed))`,
//!    immediate compute close, kick safety net). One worker at
//!    `idle_release_s = 0` makes the decision stream independent of
//!    wall-clock timestamps, so threads and real I/O cannot perturb it.
//!
//! 2. **K=4 conservation** — a seeded four-shard live run with
//!    multi-input tasks whose second file is homed on a *foreign*
//!    shard must complete everything, dispatch each task exactly once,
//!    balance the per-shard tallies, and actually cross shards
//!    (`cross_fetches > 0` with `cross_in`/`cross_out` conserved).

use datadiffusion::cache::{CacheConfig, EvictionPolicy};
use datadiffusion::coordinator::core::{
    CoordinatorCore, CoreConfig, Effect, FetchPlan, FileSizes,
};
use datadiffusion::coordinator::provisioner::{AllocationPolicy, ProvisionerConfig};
use datadiffusion::coordinator::queue::Task;
use datadiffusion::coordinator::scheduler::{DispatchPolicy, SchedulerConfig};
use datadiffusion::coordinator::shard::ShardedCoordinator;
use datadiffusion::ids::{ExecutorId, FileId, TaskId};
use datadiffusion::live::{self, ComputeKind, LiveConfig, LiveFaults, LiveTask};
use datadiffusion::util::prng::Pcg64;
use datadiffusion::util::time::Micros;
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::time::Duration;

const NUM_FILES: u32 = 10;
const ACCESSES_PER_FILE: usize = 3;
const FILE_BYTES: u64 = 2048;
const SEED: u64 = 999;

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("dd-liveparity-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

/// Interleaved access sequence (f0, f1, …, f9, f0, …): re-accesses are
/// spread out so cache decisions differ per policy.
fn task_files() -> Vec<FileId> {
    (0..NUM_FILES as usize * ACCESSES_PER_FILE)
        .map(|i| FileId((i as u32) % NUM_FILES))
        .collect()
}

fn write_store(store: &Path, files: u32) {
    std::fs::create_dir_all(store).unwrap();
    for f in 0..files {
        std::fs::write(store.join(format!("f{f}.bin")), vec![f as u8; FILE_BYTES as usize])
            .unwrap();
    }
}

fn core_config(policy: DispatchPolicy, sizes: HashMap<FileId, u64>) -> CoreConfig {
    CoreConfig {
        scheduler: SchedulerConfig {
            policy,
            ..SchedulerConfig::default()
        },
        provisioner: ProvisionerConfig {
            allocation: AllocationPolicy::OneAtATime,
            idle_release_s: 0.0,
            static_provisioning: false,
            initial_nodes: 1,
            queue_tasks_per_node: (usize::MAX >> 8) as u64,
        },
        cache: CacheConfig {
            capacity_bytes: 1 << 20,
            policy: EvictionPolicy::Lru,
        },
        max_nodes: 1,
        slots_per_node: 1,
        file_sizes: FileSizes::per_file(sizes),
    }
}

/// Synchronous reference driver over the bare core: enacts effects with
/// the live driver's structure (FIFO queues, observed-report feedback)
/// but no threads, no files, no wall clock.
struct RefDriver {
    core: CoordinatorCore,
    notify: VecDeque<ExecutorId>,
    pending: VecDeque<FetchPlan>,
}

impl RefDriver {
    fn apply(&mut self, effects: Vec<Effect>) {
        let mut queue: VecDeque<Effect> = effects.into();
        while let Some(effect) = queue.pop_front() {
            match effect {
                Effect::Notify(e) => self.notify.push_back(e),
                Effect::Fetch(plan) => self.pending.push_back(plan),
                Effect::Compute { task_id, .. } => {
                    let effs = self
                        .core
                        .on_compute_done(task_id, Micros::ZERO, Micros::ZERO);
                    queue.extend(effs);
                }
                Effect::Allocate(_) | Effect::Release(_) => {
                    panic!("static 1-worker fleet must not provision: {effect:?}")
                }
            }
        }
    }

    fn pump(&mut self) {
        loop {
            while let Some(e) = self.notify.pop_front() {
                let effects = self.core.on_pickup(e, Micros::ZERO);
                self.apply(effects);
            }
            if !self.pending.is_empty()
                || self.core.queue_is_empty()
                || self.core.free_count() == 0
            {
                break;
            }
            let queue_before = self.core.queue_len();
            let effects = self.core.kick();
            if effects.is_empty() {
                break;
            }
            self.apply(effects);
            while let Some(e) = self.notify.pop_front() {
                let effects = self.core.on_pickup(e, Micros::ZERO);
                self.apply(effects);
            }
            if self.pending.is_empty() && self.core.queue_len() == queue_before {
                break;
            }
        }
    }
}

/// Replay the workload through the bare core; returns the dispatch
/// order and `(hits_local, hits_global, misses)`.
fn drive_reference(policy: DispatchPolicy) -> (Vec<TaskId>, (u64, u64, u64)) {
    let sizes: HashMap<FileId, u64> = (0..NUM_FILES).map(|f| (FileId(f), FILE_BYTES)).collect();
    let core = CoordinatorCore::new(core_config(policy, sizes), Pcg64::seeded(SEED));
    let mut drv = RefDriver {
        core,
        notify: VecDeque::new(),
        pending: VecDeque::new(),
    };
    let (_, effects) = drv.core.register_node(Micros::ZERO);
    drv.apply(effects);
    for (i, f) in task_files().into_iter().enumerate() {
        let effects = drv.core.on_arrival(
            Task {
                id: TaskId(i as u64),
                files: vec![f],
                compute: Micros::ZERO,
                arrival: Micros::ZERO,
            },
            0,
            0.0,
            Micros::ZERO,
        );
        drv.apply(effects);
    }
    drv.pump();
    let total = task_files().len();
    let mut closed = 0usize;
    while closed < total {
        let plan = drv
            .pending
            .pop_front()
            .unwrap_or_else(|| panic!("reference stalled after {closed}/{total} fetches"));
        // One worker: the observed outcome is exactly the plan (a peer
        // copy is impossible, so no fallback path can diverge).
        let effects =
            drv.core
                .on_fetch_done(plan.task_id, Micros::ZERO, Some((plan.kind, plan.bytes)));
        closed += 1;
        drv.apply(effects);
        drv.pump();
    }
    let order = drv.core.take_dispatch_log();
    (order, drv.core.rec.access_counts())
}

fn live_config(policy: DispatchPolicy, store: PathBuf, caches: PathBuf) -> LiveConfig {
    LiveConfig {
        initial_workers: 1,
        max_workers: 1,
        queue_tasks_per_worker: usize::MAX >> 8,
        allocation: AllocationPolicy::OneAtATime,
        policy,
        cache: CacheConfig {
            capacity_bytes: 1 << 20,
            policy: EvictionPolicy::Lru,
        },
        persistent_dir: store,
        cache_root: caches,
        compute: ComputeKind::Sleep(Duration::ZERO),
        seed: SEED,
        idle_release_s: 0.0,
        shards: 1,
        faults: LiveFaults::default(),
    }
}

#[test]
fn k1_live_replays_bare_core_bit_for_bit() {
    for policy in [
        DispatchPolicy::GoodCacheCompute,
        DispatchPolicy::MaxComputeUtil,
        DispatchPolicy::FirstAvailable,
    ] {
        let (want_order, want_counts) = drive_reference(policy);
        assert_eq!(want_order.len(), task_files().len(), "[{policy}] reference");

        let root = tmp(&format!("k1-{policy}"));
        let store = root.join("store");
        write_store(&store, NUM_FILES);
        let tasks: Vec<LiveTask> = task_files()
            .into_iter()
            .map(|f| LiveTask::single(format!("f{}.bin", f.0), f))
            .collect();
        let cfg = live_config(policy, store, root.join("caches"));
        let report = live::run(&cfg, &tasks).expect("live run");
        assert_eq!(report.completed, task_files().len() as u64, "[{policy}]");
        assert_eq!(report.failed, 0, "[{policy}]");

        assert_eq!(
            report.dispatch_order, want_order,
            "[{policy}] live dispatch order diverged from the bare core"
        );
        assert_eq!(
            (report.hits_local, report.hits_global, report.misses),
            want_counts,
            "[{policy}] live access tallies diverged from the bare core"
        );
        let _ = std::fs::remove_dir_all(&root);
    }
}

/// Find file ids for a K-shard router until every shard holds at least
/// `per_shard` ids (the router's home hash is pure, so a probe router
/// with any config reports the same homes the live run will use).
fn files_by_shard(k: usize, per_shard: usize) -> Vec<Vec<FileId>> {
    let mut cfg = core_config(DispatchPolicy::FirstAvailable, HashMap::new());
    cfg.max_nodes = k; // the router asserts max_nodes >= shards
    let probe = ShardedCoordinator::new(cfg, k, Pcg64::seeded(1));
    let mut by_shard: Vec<Vec<FileId>> = vec![Vec::new(); k];
    for raw in 0..4096u32 {
        let f = FileId(raw);
        let s = probe.shard_of_file(f);
        if by_shard[s].len() < per_shard {
            by_shard[s].push(f);
        }
        if by_shard.iter().all(|v| v.len() >= per_shard) {
            return by_shard;
        }
    }
    panic!("router hash left a shard empty over 4096 file ids: {by_shard:?}");
}

#[test]
fn k4_sharded_live_run_conserves_every_tally() {
    const K: usize = 4;
    let by_shard = files_by_shard(K, 2);
    let all_files: Vec<FileId> = by_shard.iter().flatten().copied().collect();

    let root = tmp("k4");
    let store = root.join("store");
    std::fs::create_dir_all(&store).unwrap();
    let name_of = |f: FileId| format!("f{}.bin", f.0);
    for &f in &all_files {
        std::fs::write(store.join(name_of(f)), vec![f.0 as u8; FILE_BYTES as usize]).unwrap();
    }

    // Singles first (3× per file, seeding every shard's caches), then
    // one pair task per shard whose second input is homed on the next
    // shard over — by then the foreign file is cached there, so the
    // chained fetch must rewrite into a cross-shard copy. Each shard's
    // pair sits behind six singles (≥ 12ms of sleep compute) while the
    // foreign file it wants is the *first* task on its home shard
    // (~2ms), so the replica exists long before the pair's second fetch
    // is planned.
    let mut tasks: Vec<LiveTask> = Vec::new();
    for _ in 0..ACCESSES_PER_FILE {
        for &f in &all_files {
            tasks.push(LiveTask::single(name_of(f), f));
        }
    }
    let mut pair_count = 0u64;
    for s in 0..K {
        let g = by_shard[s][0];
        let foreign = by_shard[(s + 1) % K][0];
        tasks.push(LiveTask {
            file_name: name_of(g),
            file: g,
            extra: vec![(foreign, name_of(foreign))],
        });
        pair_count += 1;
    }
    let total_tasks = tasks.len() as u64;
    let total_accesses = (all_files.len() * ACCESSES_PER_FILE) as u64 + 2 * pair_count;

    let mut cfg = live_config(
        DispatchPolicy::GoodCacheCompute,
        store,
        root.join("caches"),
    );
    cfg.initial_workers = K;
    cfg.max_workers = K;
    cfg.shards = K;
    // Real (small) compute so per-shard progress rates stay comparable
    // and the singles-before-pairs ordering above is honored in time.
    cfg.compute = ComputeKind::Sleep(Duration::from_millis(2));
    let report = live::run(&cfg, &tasks).expect("sharded live run");

    assert_eq!(report.completed, total_tasks);
    assert_eq!(report.failed, 0);

    // Each task dispatched exactly once, and the per-shard dispatch
    // tallies partition the total.
    assert_eq!(report.dispatch_order.len() as u64, total_tasks);
    let mut ids: Vec<u64> = report.dispatch_order.iter().map(|t| t.0).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len() as u64, total_tasks, "a task was dispatched twice");
    let shard = &report.shard;
    assert_eq!(shard.shards, K);
    assert_eq!(
        shard.per_shard.iter().map(|s| s.dispatches).sum::<u64>(),
        total_tasks
    );
    assert_eq!(
        shard.per_shard.iter().map(|s| s.tasks_routed).sum::<u64>(),
        total_tasks
    );
    assert!(
        shard.per_shard.iter().all(|s| s.tasks_routed > 0),
        "a shard was never routed a task: {:?}",
        shard.per_shard
    );

    // Every file access lands in exactly one tally bucket.
    assert_eq!(
        report.hits_local + report.hits_global + report.misses,
        total_accesses
    );

    // The pair tasks really crossed shards, and the cross accounting is
    // conserved: one `cross_in` + one `cross_out` per rewritten fetch.
    assert!(shard.cross_fetches > 0, "no fetch ever crossed shards");
    assert_eq!(
        shard.per_shard.iter().map(|s| s.cross_in).sum::<u64>(),
        shard.cross_fetches
    );
    assert_eq!(
        shard.per_shard.iter().map(|s| s.cross_out).sum::<u64>(),
        shard.cross_fetches
    );
    assert!(shard.cross_bytes >= shard.cross_fetches * FILE_BYTES);

    // Round-robin registration staffed every shard's pool.
    assert_eq!(report.workers_per_shard.len(), K);
    assert!(
        report.workers_per_shard.iter().all(|&w| w > 0),
        "a shard never had a worker: {:?}",
        report.workers_per_shard
    );
    assert_eq!(report.partition_fallbacks, 0, "no partition was injected");

    let _ = std::fs::remove_dir_all(&root);
}
