//! Differential property tests: the indexed scheduler (inverted pending
//! index + bitset location index + window-boundary cursor) must produce
//! **bit-identical dispatch decisions** to the retained reference
//! implementation of the O(min(|Q|, W)) window scan
//! ([`Scheduler::pick_refs_reference`]) — same tasks, same order, same
//! tie-break (class asc, misses asc, queue order) — across all five
//! dispatch policies, arbitrary queue/index/registry churn, and window
//! boundaries deep inside the queue.
//!
//! Since §Perf iteration 4 the engine-default pending index is
//! **epoch-lazy** (`PendingIndex::new()`): cache events defer hot-file
//! candidate maintenance to the next consult. Every scenario here
//! therefore drives *three* implementations in lockstep — the lazy
//! index the scheduler consults, an **eager mirror**
//! (`PendingIndex::eager()`, the always-exact reference) fed the same
//! events, and the reference window scan — and checks that dispatch
//! decisions agree and both index flavors match a from-scratch rebuild.
//! The hot-file test at the bottom is the fig11-regime regression: one
//! popular file with ~2K queued readers under LRU eviction churn, where
//! the lazy path must do strictly less maintenance work than the eager
//! reference while dispatching identically.
//!
//! Phase 1 (`select_notify`) is checked against a naive re-derivation of
//! the notify scoring as well, so both halves of the §3.2 algorithm are
//! pinned by an executable specification.

use datadiffusion::cache::{CacheConfig, EvictionPolicy, ObjectCache};
use datadiffusion::coordinator::executor::ExecutorRegistry;
use datadiffusion::coordinator::pending::{remove_queued, PendingIndex};
use datadiffusion::coordinator::queue::{Task, WaitQueue};
use datadiffusion::coordinator::resolve_access;
use datadiffusion::coordinator::scheduler::{
    DispatchPolicy, NotifyOutcome, Scheduler, SchedulerConfig,
};
use datadiffusion::ids::{ExecutorId, FileId, TaskId};
use datadiffusion::index::LocationIndex;
use datadiffusion::util::prng::Pcg64;
use datadiffusion::util::proptest::{property, Gen};
use datadiffusion::util::time::Micros;
use std::collections::BTreeMap;

fn task(i: u64, files: Vec<FileId>) -> Task {
    Task {
        id: TaskId(i),
        files,
        compute: Micros::ZERO,
        arrival: Micros::ZERO,
    }
}

/// Naive re-derivation of the phase-1 notify decision (scores recounted
/// through a sorted map; rotation read from the scheduler's hint). This
/// is exactly the per-call holder-overlap recount the memoized
/// `PendingIndex::head_ranked` path retired — kept here as the spec.
fn reference_select_notify(
    sched: &Scheduler,
    files: &[FileId],
    reg: &ExecutorRegistry,
    index: &LocationIndex,
) -> NotifyOutcome {
    let cfg = &sched.config;
    let rotate = |reg: &ExecutorRegistry| match reg.next_free(sched.free_hint()) {
        Some(e) => NotifyOutcome::Fallback(e),
        None => NotifyOutcome::NoneFree,
    };
    if reg.free_count() == 0 {
        return NotifyOutcome::NoneFree;
    }
    if cfg.policy == DispatchPolicy::FirstAvailable {
        return rotate(reg);
    }
    let mut scores: BTreeMap<ExecutorId, usize> = BTreeMap::new();
    let mut any_holder = false;
    for &f in files {
        if let Some(holders) = index.holders(f) {
            for e in holders {
                any_holder = true;
                *scores.entry(e).or_insert(0) += 1;
            }
        }
    }
    let mut best: Option<(usize, ExecutorId)> = None;
    for (&e, &s) in &scores {
        if reg.is_free(e) {
            let better = match best {
                None => true,
                Some((bs, be)) => s > bs || (s == bs && e < be),
            };
            if better {
                best = Some((s, e));
            }
        }
    }
    if let Some((_, e)) = best {
        return NotifyOutcome::Preferred(e);
    }
    if cfg.policy == DispatchPolicy::FirstCacheAvailable {
        return rotate(reg);
    }
    let wait_for_holder = match cfg.policy {
        DispatchPolicy::MaxCacheHit => true,
        DispatchPolicy::MaxComputeUtil => false,
        DispatchPolicy::GoodCacheCompute => reg.cpu_utilization() >= cfg.cpu_util_threshold,
        DispatchPolicy::FirstAvailable | DispatchPolicy::FirstCacheAvailable => {
            unreachable!("handled above")
        }
    };
    if any_holder && wait_for_holder {
        NotifyOutcome::Wait
    } else {
        rotate(reg)
    }
}

/// One evolving scenario: shared queue/index/registry state, every
/// pickup decision compared between the indexed (epoch-lazy) path and
/// the reference scan *before* it is applied, with an eager pending
/// index mirrored alongside.
struct Scenario {
    sched: Scheduler,
    reg: ExecutorRegistry,
    index: LocationIndex,
    queue: WaitQueue,
    /// What the scheduler consults (engine default: epoch-lazy).
    pending: PendingIndex,
    /// The always-exact reference, fed the identical event stream.
    mirror: PendingIndex,
    execs: Vec<ExecutorId>,
    /// Shadow busy counts (slot accounting for start/finish toggles).
    busy: Vec<u32>,
    caching: bool,
    next_task: u64,
}

impl Scenario {
    fn new(policy: DispatchPolicy, n_exec: usize, window_multiplier: usize) -> Scenario {
        let mut reg = ExecutorRegistry::new();
        let mut index = LocationIndex::new();
        let caching = policy.uses_caching();
        let execs: Vec<ExecutorId> = (0..n_exec)
            .map(|_| reg.register(2, Micros::ZERO))
            .collect();
        if caching {
            for &e in &execs {
                index.register_executor(e);
            }
        }
        Scenario {
            sched: Scheduler::new(SchedulerConfig {
                policy,
                window_multiplier,
                ..SchedulerConfig::default()
            }),
            reg,
            index,
            queue: WaitQueue::new(),
            pending: PendingIndex::new(),
            mirror: PendingIndex::eager(),
            execs,
            busy: vec![0; n_exec],
            caching,
            next_task: 0,
        }
    }

    fn push_task(&mut self, files: Vec<FileId>) {
        let t = task(self.next_task, files);
        self.next_task += 1;
        let qref = self.queue.push_back(t);
        if self.caching {
            self.pending.on_push(&self.queue, qref, &self.index);
            self.mirror.on_push(&self.queue, qref, &self.index);
        }
    }

    fn index_add(&mut self, f: FileId, e: ExecutorId) {
        if !self.caching {
            return;
        }
        self.index.add(f, e);
        self.pending.on_index_add(f, e);
        self.mirror.on_index_add(f, e);
    }

    fn index_remove(&mut self, f: FileId, e: ExecutorId) {
        if !self.caching {
            return;
        }
        self.index.remove(f, e);
        self.pending.on_index_remove(f, e, &self.queue, &self.index);
        self.mirror.on_index_remove(f, e, &self.queue, &self.index);
    }

    /// Route one file access through a real cache (LRU eviction churn),
    /// mirroring the engines' `resolve_access` maintenance exactly.
    fn fetch(&mut self, exec_i: usize, f: FileId, cache: &mut ObjectCache, rng: &mut Pcg64) {
        let e = self.execs[exec_i];
        let res = resolve_access(e, f, 1, cache, &mut self.index, rng);
        for &old in &res.evicted {
            self.pending.on_index_remove(old, e, &self.queue, &self.index);
            self.mirror.on_index_remove(old, e, &self.queue, &self.index);
        }
        if res.inserted {
            self.pending.on_index_add(f, e);
            self.mirror.on_index_add(f, e);
        }
    }

    /// Compare phase 1 on the current head-of-queue file set.
    fn check_notify(&mut self) -> Result<(), String> {
        let Some(head) = self.queue.front() else {
            return Ok(());
        };
        let files = head.files.clone();
        let expected = reference_select_notify(&self.sched, &files, &self.reg, &self.index);
        let got = self
            .sched
            .select_notify(&files, &self.reg, &mut self.pending, &self.index);
        if got != expected {
            return Err(format!(
                "select_notify diverged: indexed {got:?} vs reference {expected:?}"
            ));
        }
        Ok(())
    }

    /// Compare phase 2 for one executor, then apply the dispatch (to the
    /// queue, the lazy index, and the eager mirror alike).
    fn check_pickup(&mut self, exec_i: usize, limit: usize) -> Result<Vec<Task>, String> {
        let exec = self.execs[exec_i];
        let expected_refs =
            self.sched
                .pick_refs_reference(exec, limit, &self.queue, &self.reg, &self.index);
        let expected: Vec<u64> = expected_refs
            .iter()
            .map(|&r| self.queue.get(r).id.0)
            .collect();
        // The mirror needs (files, seq) of each removed task; capture
        // before pick_tasks removes them through the lazy path.
        let removed: Vec<(Vec<FileId>, u64)> = expected_refs
            .iter()
            .map(|&r| (self.queue.get(r).files.clone(), self.queue.seq_of(r)))
            .collect();
        let got = self.sched.pick_tasks(
            exec,
            limit,
            &mut self.queue,
            &mut self.pending,
            &self.reg,
            &self.index,
        );
        let got_ids: Vec<u64> = got.iter().map(|t| t.id.0).collect();
        if got_ids != expected {
            return Err(format!(
                "pick_tasks diverged for {exec} (limit {limit}, window {}): \
                 indexed {got_ids:?} vs reference {expected:?}",
                self.sched.window_size(&self.reg)
            ));
        }
        if self.caching {
            for (files, seq) in &removed {
                self.mirror.on_remove(files, *seq, &self.index);
            }
        }
        Ok(got)
    }

    fn consistent(&mut self) -> Result<(), String> {
        self.index.check_consistent()?;
        if self.caching {
            self.pending.check_consistent(&self.queue, &self.index)?;
            self.mirror.check_consistent(&self.queue, &self.index)?;
        }
        Ok(())
    }
}

/// Random-churn differential property: pushes, cache add/evict, busy
/// toggles, and pickups interleaved arbitrarily; every decision must
/// match the reference. Small window multipliers push the boundary deep
/// into the queue so the cursor logic is stressed too.
#[test]
fn indexed_scheduler_matches_reference_under_churn() {
    for policy in DispatchPolicy::ALL {
        property(
            &format!("sched parity churn [{policy}]"),
            30,
            |g: &mut Gen| {
                let n_exec = g.usize_in(1..7);
                let window_multiplier = g.usize_in(1..5);
                let mut sc = Scenario::new(policy, n_exec, window_multiplier);
                let n_files = 15u64;
                for step in 0..g.usize_in(10..250) {
                    match g.usize_in(0..10) {
                        0..=3 => {
                            let nf = g.usize_in(1..4);
                            let files: Vec<FileId> =
                                (0..nf).map(|_| FileId(g.u64_in(0..n_files) as u32)).collect();
                            sc.push_task(files);
                        }
                        4 | 5 => {
                            let f = FileId(g.u64_in(0..n_files) as u32);
                            let e = sc.execs[g.usize_in(0..sc.execs.len())];
                            sc.index_add(f, e);
                        }
                        6 => {
                            let f = FileId(g.u64_in(0..n_files) as u32);
                            let e = sc.execs[g.usize_in(0..sc.execs.len())];
                            sc.index_remove(f, e);
                        }
                        7 => {
                            // Toggle one executor slot busy/free (varies
                            // utilization → gcc mode flips, and the free
                            // set seen by notify).
                            let i = g.usize_in(0..sc.execs.len());
                            let e = sc.execs[i];
                            if sc.busy[i] < 2 && g.bool(0.6) {
                                sc.reg.start_task(e, Micros::ZERO);
                                sc.busy[i] += 1;
                            } else if sc.busy[i] > 0 {
                                sc.reg.finish_task(e, Micros::ZERO);
                                sc.busy[i] -= 1;
                            }
                        }
                        _ => {
                            sc.check_notify()?;
                            let i = g.usize_in(0..sc.execs.len());
                            let limit = g.usize_in(1..4);
                            sc.check_pickup(i, limit)?;
                        }
                    }
                    if step % 16 == 0 {
                        sc.consistent()?;
                    }
                }
                sc.consistent()
            },
        );
    }
}

/// Deterministic ~1K-task drain per policy: batch-submit, then serve
/// pickups (with dispatch-time cache/index updates like the engines'
/// data path) until the queue drains; every decision is compared.
#[test]
fn thousand_task_drain_matches_reference_for_every_policy() {
    for policy in DispatchPolicy::ALL {
        let mut rng = Pcg64::seeded(0xd1ff ^ policy as u64);
        let n_exec = 6;
        let mut sc = Scenario::new(policy, n_exec, 3); // window = 18 « |Q|
        let n_files = 120u64;
        for _ in 0..1_000 {
            let files = vec![FileId(rng.below(n_files) as u32)];
            sc.push_task(files);
        }
        // Per-exec FIFO of cached files (simulated cache of 25 objects).
        let mut cached: Vec<Vec<FileId>> = vec![Vec::new(); n_exec];
        let mut drained = 0u64;
        let mut spins = 0u32;
        while !sc.queue.is_empty() {
            let i = (drained as usize + spins as usize) % n_exec;
            sc.check_notify().unwrap_or_else(|e| panic!("[{policy}] {e}"));
            let got = sc
                .check_pickup(i, 1 + (drained % 3) as usize)
                .unwrap_or_else(|e| panic!("[{policy}] {e}"));
            if got.is_empty() {
                // max-cache-hit legitimately declines foreign work; force
                // progress like the engines' tick safety net.
                spins += 1;
                if spins > n_exec as u32 {
                    let qref = sc.queue.front_ref().expect("non-empty");
                    let seq = sc.queue.seq_of(qref);
                    let files = sc.queue.get(qref).files.clone();
                    let t = remove_queued(&mut sc.queue, &mut sc.pending, qref, &sc.index);
                    sc.mirror.on_remove(&files, seq, &sc.index);
                    for &f in &t.files {
                        sc.index_add(f, sc.execs[i]);
                        push_cached(&mut cached[i], f, &mut sc, i);
                    }
                    drained += 1;
                    spins = 0;
                }
                continue;
            }
            spins = 0;
            for t in got {
                // Dispatch-time data path: the executor caches the files
                // (bounded cache → evict oldest), updating index+pending
                // exactly like resolve_access does in the engines.
                for &f in &t.files {
                    sc.index_add(f, sc.execs[i]);
                    push_cached(&mut cached[i], f, &mut sc, i);
                }
                drained += 1;
            }
        }
        assert_eq!(drained, 1_000, "[{policy}] tasks lost in drain");
        sc.consistent().unwrap_or_else(|e| panic!("[{policy}] {e}"));
    }
}

/// FIFO "cache" helper for the drain test: cap at 25 files per exec.
fn push_cached(cache: &mut Vec<FileId>, f: FileId, sc: &mut Scenario, exec_i: usize) {
    if !cache.contains(&f) {
        cache.push(f);
    }
    while cache.len() > 25 {
        let victim = cache.remove(0);
        let e = sc.execs[exec_i];
        sc.index_remove(victim, e);
    }
}

/// Dead-hint accounting (ROADMAP item): adversarial **leave-queue
/// churn** — a hot file (fan-out above the eager-apply cap, so its
/// evictions defer) whose readers keep leaving the queue through other
/// executors while the eviction is still pending. Every such reader
/// lingers in the first executor's candidate set as a dead hint; the
/// next consult must skip them without perturbing dispatch (checked
/// against the reference scan on every pickup), purge them on
/// encounter, and the purge count must stay within the only bound the
/// lazy design promises: one hint per (task that left the queue,
/// executor) pair.
#[test]
fn dead_hint_purges_bounded_under_leave_queue_churn() {
    use datadiffusion::coordinator::pending::FANOUT_CAP;
    let n_exec = 3usize;
    let mut sc = Scenario::new(DispatchPolicy::MaxComputeUtil, n_exec, 100);
    let e0 = sc.execs[0];
    let hot = FileId(0);
    let readers = 4 * FANOUT_CAP as u64;
    for _ in 0..readers {
        sc.push_task(vec![hot]);
    }
    let mut left_queue = 0u64;
    for _round in 0..10 {
        if sc.queue.len() < 4 {
            break;
        }
        // Cache the hot file at exec 0 (hot fan-out ⇒ deferred) and
        // materialize its candidate set through a checked pickup.
        sc.index_add(hot, e0);
        left_queue += sc.check_pickup(0, 1).unwrap().len() as u64;
        // Evict it — deferred again — …
        sc.index_remove(hot, e0);
        // … and drain readers from the head through the *other*
        // executors while the eviction is still pending: their entries
        // at exec 0 die in place (nothing sweeps them — the hot file has
        // no holders at removal time).
        for i in 1..n_exec {
            left_queue += sc.check_pickup(i, 1).unwrap().len() as u64;
        }
        // The next consult of exec 0 skips + purges the dead hints; the
        // dispatch decision still matches the reference scan (asserted
        // inside check_pickup).
        left_queue += sc.check_pickup(0, 1).unwrap().len() as u64;
    }
    sc.consistent().unwrap();
    let purged = sc.pending.stats.dead_hints_purged;
    assert!(purged > 0, "adversarial churn must produce dead hints");
    assert!(
        purged <= left_queue * n_exec as u64,
        "purged {purged} exceeds the {left_queue}×{n_exec} leave-queue bound"
    );
    // The eager mirror never defers, so it can never hold a dead hint.
    assert_eq!(
        sc.mirror.stats.dead_hints_purged, 0,
        "eager maintenance must not create dead hints"
    );
}

/// The fig11-regime regression (ROADMAP "bound hot-file pending
/// maintenance"): one popular file with ~2K queued readers while
/// single-object LRU caches churn it in and out of every executor. The
/// epoch-lazy path must (a) dispatch bit-identically to the reference
/// scan, (b) match a from-scratch rebuild after refresh, and (c) do
/// strictly less candidate maintenance work than the eager mirror —
/// sub-linear in readers per event, where eager pays O(readers) per
/// hot-file insert/evict.
#[test]
fn hot_file_eviction_churn_stays_bounded_with_identical_dispatch() {
    for policy in DispatchPolicy::ALL {
        let n_exec = 6;
        let mut sc = Scenario::new(policy, n_exec, 100); // window = 600
        let hot = FileId(0);
        // ~2K hot readers with a sprinkling of cold single-file tasks
        // (cold fan-outs stay under the eager-apply cap on purpose).
        let total = 2_400u64;
        for i in 0..total {
            let f = if i % 6 == 5 {
                FileId(1 + (i % 31) as u32)
            } else {
                hot
            };
            sc.push_task(vec![f]);
        }
        // Single-object LRU caches: every fetch evicts the previous
        // object, so alternating hot/cold fetches churn the hot file.
        let mut caches: Vec<ObjectCache> = (0..n_exec)
            .map(|_| {
                ObjectCache::new(CacheConfig {
                    capacity_bytes: 1,
                    policy: EvictionPolicy::Lru,
                })
            })
            .collect();
        let mut rng = Pcg64::seeded(0x407f11e);
        for round in 0..600usize {
            let i = round % n_exec;
            if sc.caching {
                let f = if round % 5 < 3 {
                    hot
                } else {
                    FileId(1 + (round % 31) as u32)
                };
                sc.fetch(i, f, &mut caches[i], &mut rng);
            }
            if round % 24 == 0 {
                sc.check_notify()
                    .unwrap_or_else(|e| panic!("[{policy}] {e}"));
                sc.check_pickup(i, 1)
                    .unwrap_or_else(|e| panic!("[{policy}] {e}"));
            }
        }
        sc.consistent().unwrap_or_else(|e| panic!("[{policy}] {e}"));
        if sc.caching {
            let lazy = &sc.pending.stats;
            let eager = &sc.mirror.stats;
            assert_eq!(
                lazy.index_events, eager.index_events,
                "[{policy}] both flavors must see the same event stream"
            );
            assert!(
                lazy.dirty_records > 0,
                "[{policy}] hot-file events must defer, not fan out"
            );
            assert!(
                lazy.maintenance_ops * 4 < eager.maintenance_ops,
                "[{policy}] lazy maintenance ({}) not well below eager ({})",
                lazy.maintenance_ops,
                eager.maintenance_ops
            );
        }
    }
}

/// Slab-growth regression (the arena/SoA satellite): 2.4K queued tasks
/// while executors repeatedly leave and rejoin. Each deregistration
/// parks the freed candidate set — cleared, capacity intact — in the
/// pool, and every rejoin must recycle a pooled set instead of
/// allocating a fresh one, so the capacity-based table footprint
/// plateaus after a warm-up instead of growing one slab per churn
/// cycle. Dispatch parity is re-checked inside every cycle, so the
/// recycling cannot buy its bound by perturbing decisions.
#[test]
fn slab_footprint_plateaus_under_leave_rejoin_churn() {
    let n_exec = 4usize;
    let mut sc = Scenario::new(DispatchPolicy::MaxComputeUtil, n_exec, 100);
    let execs = sc.execs.clone();
    let hot = FileId(0);
    for i in 0..2_400u64 {
        let f = if i % 8 == 7 {
            FileId(1 + (i % 13) as u32)
        } else {
            hot
        };
        sc.push_task(vec![f]);
    }
    let cycles = 12usize;
    let warm_up = 6usize;
    let mut plateau = (0u64, 0u64);
    for cycle in 0..cycles {
        // Executors 1..n leave (their candidate sets park in the pool)…
        for &e in &execs[1..] {
            sc.pending.on_deregister(e);
            sc.mirror.on_deregister(e);
        }
        // …and rejoin through real index events against the hot file,
        // which re-registers their candidate state (pool first).
        for &e in &execs[1..] {
            sc.index_add(hot, e);
            sc.index_remove(hot, e);
        }
        // Dispatch must stay bit-identical to the reference mid-churn.
        sc.check_pickup(0, 1)
            .unwrap_or_else(|e| panic!("cycle {cycle}: {e}"));
        let bytes = (sc.pending.table_bytes(), sc.mirror.table_bytes());
        if cycle < warm_up {
            plateau = (plateau.0.max(bytes.0), plateau.1.max(bytes.1));
        } else {
            assert!(
                bytes.0 <= plateau.0 && bytes.1 <= plateau.1,
                "cycle {cycle}: table footprint still growing after warm-up \
                 (lazy {} vs plateau {}, eager {} vs plateau {}) — rejoins \
                 are allocating instead of recycling pooled sets",
                bytes.0,
                plateau.0,
                bytes.1,
                plateau.1
            );
        }
    }
    assert!(
        sc.pending.stats.slab_reuse > 0,
        "leave/rejoin churn never recycled a pooled candidate set"
    );
    assert_eq!(
        sc.pending.stats.slab_reuse, sc.mirror.stats.slab_reuse,
        "both flavors see the same churn, so reuse counts must agree"
    );
    sc.consistent().unwrap();
}
