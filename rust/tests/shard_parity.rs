//! Shard-router parity: the K = 1 [`ShardedCoordinator`] must be a
//! **bit-identical pass-through** over a bare [`CoordinatorCore`], and a
//! K = 4 deployment must obey the conservation laws sharding promises.
//!
//! Part 1 (pass-through): a scripted synchronous driver — the minimal
//! enactment loop over the effect API — runs the *same* seeded workload
//! (single- and multi-file tasks, eviction churn, periodic ticks, a
//! kick-drain) against a bare core and a 1-shard router, recording every
//! event's full effect list as a string trace. The traces, dispatch
//! orders, and access tallies must be identical across **all five
//! dispatch policies**. This is what lets the sim engine drive the
//! router unconditionally: `cluster.shards = 1` provably changes
//! nothing.
//!
//! Part 2 (conservation at K = 4): a seeded run whose multi-file tasks
//! are constructed to straddle shard boundaries (dominant file on one
//! shard, secondary file homed on another) must dispatch every task
//! exactly once, account every file access exactly once across the
//! merged recorders, and produce a nonzero `shard/cross_fetches` count
//! bounded by one per routed task — the cross-shard peer-fetch protocol
//! firing without double-accounting.
//!
//! Part 3 (whole engine): `sim::run` at `cluster.shards = 4` on a
//! fig-style workload completes and conserves the same totals through
//! the full event-heap/flow-net/GRAM driver.

use datadiffusion::cache::{CacheConfig, EvictionPolicy};
use datadiffusion::config::{ArrivalSpec, ExperimentConfig};
use datadiffusion::coordinator::core::{CoordinatorCore, CoreConfig, Effect, FileSizes};
use datadiffusion::coordinator::provisioner::ProvisionerConfig;
use datadiffusion::coordinator::queue::Task;
use datadiffusion::coordinator::scheduler::{DispatchPolicy, SchedulerConfig};
use datadiffusion::coordinator::shard::ShardedCoordinator;
use datadiffusion::ids::{ExecutorId, FileId, TaskId};
use datadiffusion::sim;
use datadiffusion::util::prng::Pcg64;
use datadiffusion::util::time::Micros;

const SEED: u64 = 11;

fn core_config(policy: DispatchPolicy) -> CoreConfig {
    CoreConfig {
        scheduler: SchedulerConfig {
            policy,
            ..SchedulerConfig::default()
        },
        provisioner: ProvisionerConfig::default(),
        cache: CacheConfig {
            // 5 × 10-byte objects per cache: steady eviction churn.
            capacity_bytes: 50,
            policy: EvictionPolicy::Lru,
        },
        max_nodes: 8,
        slots_per_node: 2,
        file_sizes: FileSizes::Uniform(10),
    }
}

/// The event surface both the bare core and the router expose — the
/// trait exists only so one scripted driver can drive either.
trait Coordinator {
    fn register_node(&mut self, now: Micros) -> (ExecutorId, Vec<Effect>);
    fn on_node_registered(&mut self, now: Micros) -> (ExecutorId, Vec<Effect>);
    fn release_node(&mut self, id: ExecutorId);
    fn on_arrival(&mut self, task: Task, now: Micros) -> Vec<Effect>;
    fn on_pickup(&mut self, exec: ExecutorId, now: Micros) -> Vec<Effect>;
    fn on_fetch_done(&mut self, task: TaskId, now: Micros) -> Vec<Effect>;
    fn on_compute_done(&mut self, task: TaskId, now: Micros) -> Vec<Effect>;
    fn on_tick(&mut self, now: Micros) -> Vec<Effect>;
    fn kick(&mut self) -> Vec<Effect>;
    fn queue_len(&self) -> usize;
    /// End-of-run: `(access tallies, dispatch order)`.
    fn finish(&mut self) -> ((u64, u64, u64), Vec<TaskId>);
}

impl Coordinator for CoordinatorCore {
    fn register_node(&mut self, now: Micros) -> (ExecutorId, Vec<Effect>) {
        CoordinatorCore::register_node(self, now)
    }
    fn on_node_registered(&mut self, now: Micros) -> (ExecutorId, Vec<Effect>) {
        CoordinatorCore::on_node_registered(self, now)
    }
    fn release_node(&mut self, id: ExecutorId) {
        CoordinatorCore::release_node(self, id);
    }
    fn on_arrival(&mut self, task: Task, now: Micros) -> Vec<Effect> {
        CoordinatorCore::on_arrival(self, task, 0, 0.0, now)
    }
    fn on_pickup(&mut self, exec: ExecutorId, now: Micros) -> Vec<Effect> {
        CoordinatorCore::on_pickup(self, exec, now)
    }
    fn on_fetch_done(&mut self, task: TaskId, now: Micros) -> Vec<Effect> {
        CoordinatorCore::on_fetch_done(self, task, now, None)
    }
    fn on_compute_done(&mut self, task: TaskId, now: Micros) -> Vec<Effect> {
        CoordinatorCore::on_compute_done(self, task, now, now)
    }
    fn on_tick(&mut self, now: Micros) -> Vec<Effect> {
        CoordinatorCore::on_tick(self, now)
    }
    fn kick(&mut self) -> Vec<Effect> {
        CoordinatorCore::kick(self)
    }
    fn queue_len(&self) -> usize {
        CoordinatorCore::queue_len(self)
    }
    fn finish(&mut self) -> ((u64, u64, u64), Vec<TaskId>) {
        (self.rec.access_counts(), self.take_dispatch_log())
    }
}

impl Coordinator for ShardedCoordinator {
    fn register_node(&mut self, now: Micros) -> (ExecutorId, Vec<Effect>) {
        ShardedCoordinator::register_node(self, now)
    }
    fn on_node_registered(&mut self, now: Micros) -> (ExecutorId, Vec<Effect>) {
        ShardedCoordinator::on_node_registered(self, now)
    }
    fn release_node(&mut self, id: ExecutorId) {
        ShardedCoordinator::release_node(self, id);
    }
    fn on_arrival(&mut self, task: Task, now: Micros) -> Vec<Effect> {
        ShardedCoordinator::on_arrival(self, task, 0, 0.0, now)
    }
    fn on_pickup(&mut self, exec: ExecutorId, now: Micros) -> Vec<Effect> {
        ShardedCoordinator::on_pickup(self, exec, now)
    }
    fn on_fetch_done(&mut self, task: TaskId, now: Micros) -> Vec<Effect> {
        ShardedCoordinator::on_fetch_done(self, task, now, None)
    }
    fn on_compute_done(&mut self, task: TaskId, now: Micros) -> Vec<Effect> {
        ShardedCoordinator::on_compute_done(self, task, now, now)
    }
    fn on_tick(&mut self, now: Micros) -> Vec<Effect> {
        ShardedCoordinator::on_tick(self, now)
    }
    fn kick(&mut self) -> Vec<Effect> {
        ShardedCoordinator::kick(self)
    }
    fn queue_len(&self) -> usize {
        ShardedCoordinator::queue_len(self)
    }
    fn finish(&mut self) -> ((u64, u64, u64), Vec<TaskId>) {
        let log = self.take_dispatch_log();
        (self.take_merged_recorder().access_counts(), log)
    }
}

/// Synchronously enact `effects`, recording every event's effect list.
fn pump<C: Coordinator>(c: &mut C, effects: Vec<Effect>, now: Micros, trace: &mut Vec<String>) {
    let mut stack = effects;
    while let Some(effect) = stack.pop() {
        match effect {
            Effect::Notify(e) => {
                let effs = c.on_pickup(e, now);
                trace.push(format!("pickup {e:?} -> {effs:?}"));
                stack.extend(effs);
            }
            Effect::Fetch(plan) => {
                let effs = c.on_fetch_done(plan.task_id, now);
                trace.push(format!("fetch-done {:?} -> {effs:?}", plan.task_id));
                stack.extend(effs);
            }
            Effect::Compute { task_id, .. } => {
                let effs = c.on_compute_done(task_id, now);
                trace.push(format!("compute-done {task_id:?} -> {effs:?}"));
                stack.extend(effs);
            }
            Effect::Allocate(n) => {
                for _ in 0..n {
                    let (e, effs) = c.on_node_registered(now);
                    trace.push(format!("node-up {e:?} -> {effs:?}"));
                    stack.extend(effs);
                }
            }
            Effect::Release(execs) => {
                for e in execs {
                    trace.push(format!("release {e:?}"));
                    c.release_node(e);
                }
            }
        }
    }
}

/// The scripted deterministic workload: register nodes, feed tasks with
/// periodic ticks, then kick-drain the backlog. Returns the full trace.
fn drive<C: Coordinator>(c: &mut C, nodes: usize, tasks: &[Task]) -> Vec<String> {
    let mut trace = Vec::new();
    for _ in 0..nodes {
        let (e, effs) = c.register_node(Micros::ZERO);
        trace.push(format!("register {e:?} -> {effs:?}"));
        pump(c, effs, Micros::ZERO, &mut trace);
    }
    let mut clock = Micros::ZERO;
    for (i, task) in tasks.iter().enumerate() {
        clock = Micros::from_millis(i as u64);
        let effs = c.on_arrival(task.clone(), clock);
        trace.push(format!("arrival {:?} -> {effs:?}", task.id));
        pump(c, effs, clock, &mut trace);
        if i % 7 == 0 {
            let effs = c.on_tick(clock);
            trace.push(format!("tick -> {effs:?}"));
            pump(c, effs, clock, &mut trace);
        }
    }
    let mut guard = 0;
    while c.queue_len() > 0 {
        guard += 1;
        assert!(guard < 10_000, "drain stalled with {} queued", c.queue_len());
        // Tick first so a fleet the provisioner shrank can re-allocate.
        let effs = c.on_tick(clock);
        trace.push(format!("drain-tick -> {effs:?}"));
        pump(c, effs, clock, &mut trace);
        let effs = c.kick();
        trace.push(format!("kick -> {effs:?}"));
        pump(c, effs, clock, &mut trace);
    }
    trace
}

/// Seeded task stream: 240 tasks over 40 files, 1–3 files each, so the
/// 5-object caches churn and multi-file scoring paths are exercised.
fn scripted_tasks() -> Vec<Task> {
    let mut rng = Pcg64::seeded(SEED);
    (0..240u64)
        .map(|i| {
            // 1–3 distinct files, biased to the paper's single-file shape.
            let n = match rng.below(4) {
                0 | 1 => 1,
                2 => 2,
                _ => 3,
            };
            let mut files: Vec<FileId> = Vec::with_capacity(n);
            while files.len() < n {
                let f = FileId(rng.below(40) as u32);
                if !files.contains(&f) {
                    files.push(f);
                }
            }
            Task {
                id: TaskId(i),
                files,
                compute: Micros::from_millis(1),
                arrival: Micros::ZERO,
            }
        })
        .collect()
}

#[test]
fn k1_router_is_bit_identical_to_the_bare_core() {
    for policy in DispatchPolicy::ALL {
        let tasks = scripted_tasks();
        let mut core = CoordinatorCore::new(core_config(policy), Pcg64::seeded(SEED));
        let mut router = ShardedCoordinator::new(core_config(policy), 1, Pcg64::seeded(SEED));

        let core_trace = drive(&mut core, 3, &tasks);
        let router_trace = drive(&mut router, 3, &tasks);

        assert_eq!(
            core_trace.len(),
            router_trace.len(),
            "[{policy}] trace lengths diverged"
        );
        for (i, (a, b)) in core_trace.iter().zip(&router_trace).enumerate() {
            assert_eq!(a, b, "[{policy}] traces diverge at event {i}");
        }
        let (core_counts, core_log) = core.finish();
        let (router_counts, router_log) = router.finish();
        assert_eq!(core_log, router_log, "[{policy}] dispatch order diverged");
        assert_eq!(core_counts, router_counts, "[{policy}] tallies diverged");
        assert_eq!(core_log.len(), tasks.len(), "[{policy}] tasks missing");
        assert_eq!(
            router.counters().cross_fetches,
            0,
            "[{policy}] K=1 must never cross shards"
        );
    }
}

#[test]
fn k4_conserves_totals_and_crosses_shards() {
    let mut router = ShardedCoordinator::new(
        core_config(DispatchPolicy::GoodCacheCompute),
        4,
        Pcg64::seeded(SEED),
    );
    let mut trace = Vec::new();
    for _ in 0..8 {
        let (_, effs) = router.register_node(Micros::ZERO);
        pump(&mut router, effs, Micros::ZERO, &mut trace);
    }
    // One file homed on each shard (found by probing the partition
    // function), so the workload provably covers every shard.
    let home: Vec<FileId> = (0..4)
        .map(|s| {
            (0..1_000u32)
                .map(FileId)
                .find(|&f| router.shard_of_file(f) == s)
                .expect("splitmix spreads over 4 shards")
        })
        .collect();

    // Phase 1: seed each shard's cache with its home file.
    let mut tasks: Vec<Task> = Vec::new();
    for (s, &f) in home.iter().enumerate() {
        tasks.push(Task {
            id: TaskId(s as u64),
            files: vec![f],
            compute: Micros::from_millis(1),
            arrival: Micros::ZERO,
        });
    }
    // Phase 2: every ordered cross-shard pair (dominant on s, secondary
    // homed on t ≠ s) — the secondary fetch must find its bytes on the
    // foreign shard, not GPFS.
    let mut id = home.len() as u64;
    for s in 0..4usize {
        for t in 0..4usize {
            if s == t {
                continue;
            }
            tasks.push(Task {
                id: TaskId(id),
                files: vec![home[s], home[t]],
                compute: Micros::from_millis(1),
                arrival: Micros::ZERO,
            });
            id += 1;
        }
    }
    let expected_accesses: u64 = tasks.iter().map(|t| t.files.len() as u64).sum();

    let mut clock = Micros::ZERO;
    for (i, task) in tasks.iter().enumerate() {
        clock = Micros::from_millis(i as u64);
        let effs = router.on_arrival(task.clone(), 0, 0.0, clock);
        pump(&mut router, effs, clock, &mut trace);
    }
    let mut guard = 0;
    while router.queue_len() > 0 {
        guard += 1;
        assert!(guard < 10_000, "drain stalled");
        let effs = router.on_tick(clock);
        pump(&mut router, effs, clock, &mut trace);
        let effs = router.kick();
        pump(&mut router, effs, clock, &mut trace);
    }

    // Conservation: every task dispatched exactly once…
    let log = router.take_dispatch_log();
    assert_eq!(log.len(), tasks.len());
    let mut ids: Vec<u64> = log.iter().map(|t| t.0).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), tasks.len(), "duplicate dispatches");
    // …every access tallied exactly once across the merged recorders…
    let rec = router.take_merged_recorder();
    let (hl, hg, m) = rec.access_counts();
    assert_eq!(hl + hg + m, expected_accesses, "access conservation");
    assert_eq!(rec.tasks_done(), tasks.len() as u64);
    // …and the cross-shard protocol actually fired, bounded ≤ 1/task.
    let counters = router.take_counters();
    assert!(
        counters.cross_fetches > 0,
        "cross-shard workload produced no cross fetches"
    );
    // Pair tasks carry at most one foreign-homed file, so ≤ 1 holds here.
    assert!(counters.cross_fetches_per_task() <= 1.0);
    assert_eq!(counters.tasks_routed(), tasks.len() as u64);
    assert!(counters.per_shard.iter().all(|t| t.tasks_routed > 0));
    let cross_in: u64 = counters.per_shard.iter().map(|t| t.cross_in).sum();
    let cross_out: u64 = counters.per_shard.iter().map(|t| t.cross_out).sum();
    assert_eq!(cross_in, counters.cross_fetches, "both-sides accounting");
    assert_eq!(cross_out, counters.cross_fetches, "both-sides accounting");
    // Cross-shard transfers are recorded as global hits.
    assert!(hg >= counters.cross_fetches);
}

#[test]
fn k4_full_engine_run_completes_and_conserves() {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "shard-parity-engine".into();
    cfg.seed = 7;
    cfg.cluster.max_nodes = 8;
    cfg.cluster.shards = 4;
    cfg.workload.num_tasks = 1_000;
    cfg.workload.num_files = 100;
    cfg.workload.file_size_bytes = 10 * 1024 * 1024;
    cfg.workload.arrival = ArrivalSpec::IncreasingRate {
        initial: 4.0,
        factor: 1.5,
        interval_s: 10.0,
        max_rate: 100.0,
    };
    cfg.cache.capacity_bytes = 4_000 * 1024 * 1024;
    let r = sim::run(&cfg);
    assert_eq!(r.summary.tasks_completed, 1_000);
    assert_eq!(r.shard.shards, 4);
    assert_eq!(r.shard.tasks_routed(), 1_000);
    assert_eq!(r.dispatch_order.len(), 1_000);
    let (hl, hg, m) = r.access_counts;
    assert_eq!(hl + hg + m, 1_000);
    assert!(r.shard.router_events > 0);
    assert!(r.shard.cross_fetches_per_task() <= 1.0);
}
