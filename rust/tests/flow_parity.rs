//! Differential property tests: the batched flow-net rerating
//! ([`RerateMode::Batched`], what the engine runs) must produce
//! **bit-identical completion timestamps** to the retained per-event
//! reference path ([`RerateMode::Reference`]) — same completion times,
//! same pop order, same tags — across seeded random start/complete
//! churn over shared multi-link paths, including same-instant event
//! pileups (several starts and pops at one timestamp with no query in
//! between, zero-byte transfers completing at their start instant).
//!
//! Both networks receive the exact same op sequence; every observable
//! (next-completion time, popped tag, in-flight count, completed count)
//! is compared at every step.

use datadiffusion::sim::flow::{FlowNet, LinkId, RerateMode};
use datadiffusion::util::proptest::{property, Gen};
use datadiffusion::util::time::Micros;

/// The two networks under identical drive.
struct Pair {
    batched: FlowNet,
    reference: FlowNet,
    links: Vec<LinkId>,
    now: Micros,
    next_tag: u64,
}

impl Pair {
    fn new(g: &mut Gen) -> Pair {
        let mut batched = FlowNet::new();
        let mut reference = FlowNet::reference();
        assert_eq!(batched.mode(), RerateMode::Batched);
        assert_eq!(reference.mode(), RerateMode::Reference);
        let n = g.usize_in(2..7);
        let mut links = Vec::with_capacity(n);
        for _ in 0..n {
            // Mixed magnitudes so bottlenecks shift between links.
            let cap = g.f64_in(100.0, 1e7);
            let a = batched.add_link(cap);
            let b = reference.add_link(cap);
            assert_eq!(a, b);
            links.push(a);
        }
        Pair {
            batched,
            reference,
            links,
            now: Micros::ZERO,
            next_tag: 0,
        }
    }

    /// Pick 1–3 distinct links for a transfer path.
    fn pick_path(&self, g: &mut Gen) -> Vec<LinkId> {
        let n = self.links.len();
        let want = g.usize_in(1..4).min(n);
        let mut idx: Vec<usize> = Vec::with_capacity(want);
        while idx.len() < want {
            let i = g.usize_in(0..n);
            if !idx.contains(&i) {
                idx.push(i);
            }
        }
        idx.into_iter().map(|i| self.links[i]).collect()
    }

    fn start(&mut self, g: &mut Gen) {
        let path = self.pick_path(g);
        // ~15% zero-byte transfers: they complete at the start instant,
        // creating same-instant completion pileups.
        let bytes = if g.bool(0.15) {
            0
        } else {
            g.u64_in(1..100_000_000)
        };
        let a = self.batched.start(self.now, bytes, &path, self.next_tag);
        let b = self.reference.start(self.now, bytes, &path, self.next_tag);
        assert_eq!(a, b, "transfer handle allocation diverged");
        self.next_tag += 1;
    }

    fn check_next(&mut self) -> Result<Option<Micros>, String> {
        let a = self.batched.next_completion();
        let b = self.reference.next_completion();
        if a != b {
            return Err(format!(
                "next_completion diverged at {}: batched {a:?} vs reference {b:?}",
                self.now
            ));
        }
        Ok(a)
    }

    /// Pop the earliest completion from both nets; compare everything.
    fn pop(&mut self) -> Result<(), String> {
        let Some(t) = self.check_next()? else {
            return Ok(());
        };
        self.now = self.now.max(t);
        let ta = self.batched.pop_completion(self.now);
        let tb = self.reference.pop_completion(self.now);
        if ta != tb {
            return Err(format!(
                "pop at {} diverged: batched tag {ta} vs reference tag {tb}",
                self.now
            ));
        }
        self.check_counts()
    }

    fn check_counts(&self) -> Result<(), String> {
        if self.batched.in_flight() != self.reference.in_flight() {
            return Err(format!(
                "in_flight diverged: {} vs {}",
                self.batched.in_flight(),
                self.reference.in_flight()
            ));
        }
        if self.batched.completed != self.reference.completed {
            return Err(format!(
                "completed diverged: {} vs {}",
                self.batched.completed, self.reference.completed
            ));
        }
        for &l in &self.links {
            if self.batched.link_active(l) != self.reference.link_active(l) {
                return Err(format!("link_active({l:?}) diverged"));
            }
        }
        Ok(())
    }

    /// Advance time by a random amount, never past the next completion.
    fn advance(&mut self, g: &mut Gen) -> Result<(), String> {
        let bound = match self.check_next()? {
            Some(nc) => (nc - self.now).0,
            None => 1_000_000,
        };
        self.now = self.now + Micros(g.u64_in(0..bound + 1));
        Ok(())
    }
}

/// Random churn: starts, pops, time advances, and same-instant pileups
/// interleaved arbitrarily; every observable must match at every step.
#[test]
fn batched_rerating_matches_reference_under_churn() {
    property("flow parity churn", 60, |g: &mut Gen| {
        let mut p = Pair::new(g);
        for _ in 0..g.usize_in(20..180) {
            match g.usize_in(0..8) {
                0..=2 => p.start(g),
                3 | 4 => p.pop()?,
                5 => p.advance(g)?,
                _ => {
                    // Same-instant pileup: several starts at `now` with
                    // no query in between, then drain every completion
                    // landing exactly at `now`.
                    for _ in 0..g.usize_in(1..5) {
                        p.start(g);
                    }
                    while p.check_next()? == Some(p.now) {
                        p.pop()?;
                    }
                }
            }
            p.check_counts()?;
        }
        // Drain: the full remaining completion trace must agree.
        while p.check_next()?.is_some() {
            p.pop()?;
        }
        p.check_counts()
    });
}

/// The perf_hotpath churn shape (shared bottleneck + per-node NICs, one
/// pop + one start per instant): completion times must match exactly
/// while the batched path provably does less rerate work.
#[test]
fn bench_shape_trace_is_identical_and_cheaper() {
    let drive = |mode: RerateMode| -> (Vec<(u64, Micros)>, u64) {
        let mut net = FlowNet::with_mode(mode);
        let gpfs = net.add_link(5.5e8);
        let nics: Vec<LinkId> = (0..16).map(|_| net.add_link(1.25e8)).collect();
        let mut i = 0u64;
        for _ in 0..64 {
            net.start(Micros::ZERO, 10_000_000, &[gpfs, nics[(i % 16) as usize]], i);
            i += 1;
        }
        let mut trace = Vec::new();
        for _ in 0..500 {
            let t = net.next_completion().expect("in flight");
            let tag = net.pop_completion(t);
            trace.push((tag, t));
            net.start(t, 10_000_000, &[gpfs, nics[(i % 16) as usize]], i);
            i += 1;
        }
        (trace, net.stats.transfer_rerates)
    };
    let (batched_trace, batched_rerates) = drive(RerateMode::Batched);
    let (reference_trace, reference_rerates) = drive(RerateMode::Reference);
    assert_eq!(batched_trace, reference_trace, "completion traces diverged");
    assert!(
        batched_rerates * 3 < reference_rerates * 2,
        "batched rerates {batched_rerates} not ≪ reference {reference_rerates}"
    );
}

/// Multi-task pickups stage several transfers at one instant; a released
/// co-flow at the same instant must not perturb parity. This is the
/// smallest pileup that exercised the old epsilon-skip divergence
/// (pop+start returning a link to its prior active count).
#[test]
fn pop_start_pileup_at_same_instant() {
    let drive = |mode: RerateMode| -> Vec<(u64, Micros)> {
        let mut net = FlowNet::with_mode(mode);
        let shared = net.add_link(1_000_000.0);
        let a = net.add_link(300_000.0);
        let b = net.add_link(7_777_777.0);
        net.start(Micros::ZERO, 333_333, &[shared, a], 0);
        net.start(Micros::ZERO, 999_999, &[shared, b], 1);
        net.start(Micros::ZERO, 123_456, &[shared], 2);
        let mut trace = Vec::new();
        let mut tag = 3u64;
        for _ in 0..40 {
            let t = net.next_completion().expect("in flight");
            trace.push((net.pop_completion(t), t));
            // Same instant: two new transfers and a zero-byte flash.
            net.start(t, 777_777, &[shared, a], tag);
            net.start(t, 0, &[b], tag + 1);
            tag += 2;
            // The zero-byte transfer completes at t; drain it now.
            while net.next_completion() == Some(t) {
                trace.push((net.pop_completion(t), t));
            }
        }
        trace
    };
    assert_eq!(drive(RerateMode::Batched), drive(RerateMode::Reference));
}
