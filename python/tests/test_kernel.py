"""Pallas kernels vs pure-jnp oracles — hypothesis sweeps over shapes.

The CORE correctness signal for L1: every kernel must match its ref.py
oracle to float32 tolerance across randomized shapes and values.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import model_eval as me
from compile.kernels import ref, stacking

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


# ---------------------------------------------------------------- stacking

@given(
    n_blocks=st.integers(min_value=1, max_value=6),
    block_n=st.sampled_from([1, 2, 8, 32]),
    h=st.integers(min_value=1, max_value=48),
    w=st.integers(min_value=1, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_stack_matches_ref(n_blocks, block_n, h, w, seed):
    n = n_blocks * block_n
    cutouts = rand(seed, (n, h, w))
    weights = rand(seed + 1, (n,), 0.0, 3.0)
    got = stacking.stack(cutouts, weights, block_n=block_n)
    want = ref.ref_stack(cutouts, weights)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_stack_rejects_indivisible_batch():
    cutouts = rand(0, (10, 4, 4))
    weights = rand(1, (10,))
    with pytest.raises(AssertionError):
        stacking.stack(cutouts, weights, block_n=4)


def test_stack_zero_weights_zero_image():
    cutouts = rand(2, (32, 8, 8))
    weights = jnp.zeros((32,), jnp.float32)
    got = stacking.stack(cutouts, weights)
    np.testing.assert_allclose(got, jnp.zeros((8, 8)), atol=1e-7)


def test_stack_single_cutout_identity():
    cutouts = rand(3, (1, 16, 16))
    weights = jnp.ones((1,), jnp.float32)
    got = stacking.stack(cutouts, weights, block_n=1)
    np.testing.assert_allclose(got, cutouts[0], rtol=1e-6)


# -------------------------------------------------------------- model_eval

def model_args(seed, b):
    """Random but physically plausible model parameter batch."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 9)
    u = lambda k, lo, hi: jax.random.uniform(k, (b,), jnp.float32, lo, hi)
    return dict(
        k=u(ks[0], 1e2, 1e5),          # tasks
        cpus=u(ks[1], 1.0, 256.0),
        mu=u(ks[2], 1e-3, 10.0),       # seconds
        o=u(ks[3], 1e-4, 0.1),
        beta=u(ks[4], 1e3, 1e8),       # bytes
        inv_a=jnp.where(u(ks[5], 0.0, 1.0) < 0.3, 0.0, u(ks[6], 1e-4, 1.0)),
        nu_pi=u(ks[7], 1e7, 1e9),      # bytes/s
        nu_tau=u(ks[8], 1e7, 1e9),
        p_miss=u(ks[0], 0.0, 1.0),
    )


ARG_ORDER = ["k", "cpus", "mu", "o", "beta", "inv_a", "nu_pi", "nu_tau", "p_miss"]


@given(
    b=st.sampled_from([1, 3, 64, 129]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_model_eval_matches_ref(b, seed):
    args = model_args(seed, b)
    ordered = [args[k] for k in ARG_ORDER]
    got = me.model_eval(*ordered)
    want = ref.ref_model_eval(*ordered)
    for g, w, name in zip(got, want, ["V", "Y", "W", "E", "S", "omega", "zeta"]):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5, err_msg=name)


def test_model_eval_invariants():
    args = model_args(7, 128)
    ordered = [args[k] for k in ARG_ORDER]
    v, y, w, e, s, omega, zeta = me.model_eval(*ordered)
    assert np.all(np.asarray(w) >= np.asarray(v) * (1 - 1e-5)), "W ≥ V"
    assert np.all((np.asarray(e) > 0) & (np.asarray(e) <= 1.0 + 1e-6)), "E ∈ (0,1]"
    np.testing.assert_allclose(s, np.asarray(e) * np.asarray(args["cpus"]), rtol=1e-5)
    assert np.all(np.asarray(omega) >= 1.0), "ω ≥ 1"


def test_model_eval_zero_miss_means_no_copy_cost():
    b = 8
    args = model_args(11, b)
    args["p_miss"] = jnp.zeros((b,), jnp.float32)
    ordered = [args[k] for k in ARG_ORDER]
    v, y, w, e, s, omega, zeta = me.model_eval(*ordered)
    # Y = μ + o + local read, no ζ term; ω stays at the floor.
    expect_y = args["mu"] + args["o"] + args["beta"] / args["nu_tau"]
    np.testing.assert_allclose(y, expect_y, rtol=1e-5)
    np.testing.assert_allclose(omega, jnp.ones((b,)), rtol=1e-6)
