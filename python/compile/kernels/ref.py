"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package has a reference implementation here written
with plain jax.numpy (no Pallas, no custom control flow), checked by
``python/tests`` under hypothesis sweeps, and mirrored again on the Rust
side (``model::predict``) for the model evaluator.
"""

import jax.numpy as jnp

#: Must match model_eval.FIXED_POINT_ITERS and the Rust implementation.
FIXED_POINT_ITERS = 32


def ref_stack(cutouts, weights):
    """Weighted stack: out[h,w] = Σ_n weights[n]·cutouts[n,h,w]."""
    return jnp.sum(cutouts * weights[:, None, None], axis=0)


def ref_model_eval(k, cpus, mu, o, beta, inv_a, nu_pi, nu_tau, p_miss):
    """Abstract-model evaluation (§4.3), elementwise over (B,) arrays."""
    p_local = 1.0 - p_miss
    v = jnp.maximum(mu / cpus, inv_a) * k
    local_read = beta / nu_tau

    omega = jnp.ones_like(mu)
    zeta = beta / nu_pi
    y = mu + o + p_local * local_read + p_miss * zeta
    for _ in range(FIXED_POINT_ITERS):
        zeta = beta * jnp.maximum(omega, 1.0) / nu_pi
        y = mu + o + p_local * local_read + p_miss * zeta
        busy = jnp.where(
            inv_a > 0.0, jnp.minimum(y / jnp.maximum(inv_a, 1e-30), cpus), cpus
        )
        omega = jnp.maximum(busy * p_miss * zeta / y, 1.0)

    zeta = beta * jnp.maximum(omega, 1.0) / nu_pi
    y = mu + o + p_local * local_read + p_miss * zeta
    w = jnp.maximum(y / cpus, inv_a) * k
    e = jnp.minimum(v / w, 1.0)
    return v, y, w, e, e * cpus, omega, zeta
