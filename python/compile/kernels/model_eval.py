"""L1 Pallas kernel: batched abstract-model evaluation (§4.3).

Evaluates the data-centric task-farm model for a *batch* of parameter
points — the Figure 2 validation sweeps evaluate hundreds of (CPUs,
locality) combinations, and the Rust coordinator batch-offloads them
through this kernel's AOT artifact.

Model (paper §4.3, mirrored bit-for-bit by ``rust/src/model/mod.rs``):

    V  = max(μ/|T|, 1/A) · |K|
    Y  = μ + o + p_local·(β/ν_τ) + p_miss·ζ          (ζ = β·ω/ν_π)
    ω' = max(busy · p_miss·ζ / Y, 1)                  (fixed point, 32 it.)
    W  = max(Y/|T|, 1/A) · |K|
    E  = min(V/W, 1),  S = E·|T|

All arrays share shape (B,); the kernel is pure VPU elementwise work with
the fixed-point loop unrolled (32 steps — the same bound as the Rust
implementation). f32 in/out.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Fixed-point iterations (matches rust/src/model/mod.rs).
FIXED_POINT_ITERS = 32


def _model_kernel(k_ref, t_ref, mu_ref, o_ref, beta_ref, inva_ref, nupi_ref,
                  nutau_ref, pmiss_ref, v_ref, y_ref, w_ref, e_ref, s_ref,
                  omega_ref, zeta_ref):
    k = k_ref[...]
    cpus = t_ref[...]
    mu = mu_ref[...]
    o = o_ref[...]
    beta = beta_ref[...]
    inv_a = inva_ref[...]
    nu_pi = nupi_ref[...]
    nu_tau = nutau_ref[...]
    p_miss = pmiss_ref[...]
    p_local = 1.0 - p_miss

    v = jnp.maximum(mu / cpus, inv_a) * k
    local_read = beta / nu_tau

    omega = jnp.ones_like(mu)
    zeta = beta / nu_pi
    y = mu + o + p_local * local_read + p_miss * zeta
    for _ in range(FIXED_POINT_ITERS):
        zeta = beta * jnp.maximum(omega, 1.0) / nu_pi
        y = mu + o + p_local * local_read + p_miss * zeta
        # busy CPUs capped by the arrival rate (inv_a = 0 ⇒ batch ⇒ all).
        busy = jnp.where(inv_a > 0.0, jnp.minimum(y / jnp.maximum(inv_a, 1e-30), cpus), cpus)
        omega = jnp.maximum(busy * p_miss * zeta / y, 1.0)

    zeta = beta * jnp.maximum(omega, 1.0) / nu_pi
    y = mu + o + p_local * local_read + p_miss * zeta
    w = jnp.maximum(y / cpus, inv_a) * k
    e = jnp.minimum(v / w, 1.0)

    v_ref[...] = v
    y_ref[...] = y
    w_ref[...] = w
    e_ref[...] = e
    s_ref[...] = e * cpus
    omega_ref[...] = omega
    zeta_ref[...] = zeta


@jax.jit
def model_eval(k, cpus, mu, o, beta, inv_a, nu_pi, nu_tau, p_miss):
    """Batched model evaluation; all inputs shape (B,) f32.

    Returns (V, Y, W, E, S, ω, ζ), each (B,) f32.
    """
    (b,) = mu.shape
    shapes = [jax.ShapeDtypeStruct((b,), jnp.float32)] * 7
    return pl.pallas_call(
        _model_kernel,
        out_shape=shapes,
        interpret=True,
    )(k, cpus, mu, o, beta, inv_a, nu_pi, nu_tau, p_miss)
