"""L1 Pallas kernel: astronomy cutout stacking.

The paper's driving application (AstroPortal, refs [5][6]) stacks many
small image cutouts of the same sky object to raise signal-to-noise: the
per-task compute μ(κ) of the data-diffusion workloads. The hot loop is a
weighted sum over a batch of cutouts:

    out[h, w] = Σ_n  weight[n] · cutout[n, h, w]

TPU adaptation (DESIGN.md §Hardware-Adaptation): the batch dimension is
the Pallas grid; each grid step streams one VMEM-sized block of cutouts
from HBM and accumulates into the output block, which stays resident in
VMEM across the whole grid (classic revisiting-output schedule expressed
with a constant index_map). The multiply-accumulate is a VPU
elementwise-reduce, f32 throughout.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is asserted against the pure-jnp oracle in
``ref.py`` and real-TPU performance is *estimated* (DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stack_kernel(x_ref, w_ref, o_ref):
    """One grid step: accumulate `weight · cutout` for a block of cutouts.

    x_ref: (BN, H, W) block of cutouts in VMEM
    w_ref: (BN,)     matching weights
    o_ref: (H, W)    the full output block (revisited every step)
    """
    step = pl.program_id(0)

    # Zero the accumulator on the first visit.
    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    block = x_ref[...]  # (BN, H, W)
    weights = w_ref[...]  # (BN,)
    # Broadcast weights over the image plane and reduce the batch axis.
    o_ref[...] += jnp.sum(block * weights[:, None, None], axis=0)


@functools.partial(jax.jit, static_argnames=("block_n",))
def stack(cutouts: jax.Array, weights: jax.Array, *, block_n: int = 32) -> jax.Array:
    """Weighted stack of `cutouts` (N, H, W) with `weights` (N,) → (H, W).

    N must be divisible by `block_n` (the AOT artifact fixes N; the
    library pads on the Rust side).
    """
    n, h, w = cutouts.shape
    assert weights.shape == (n,), f"weights {weights.shape} != ({n},)"
    assert n % block_n == 0, f"N={n} not divisible by block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _stack_kernel,
        grid=grid,
        in_specs=[
            # Stream cutout blocks: grid step i reads rows [i·BN, (i+1)·BN).
            pl.BlockSpec((block_n, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        # The output block is the whole image, revisited at every step.
        out_specs=pl.BlockSpec((h, w), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), cutouts.dtype),
        interpret=True,
    )(cutouts, weights)
