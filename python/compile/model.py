"""L2: the JAX compute graphs that the Rust coordinator executes via PJRT.

Two graphs, both calling the L1 Pallas kernels so they lower into the
same HLO modules:

* :func:`stack_pipeline` — the astronomy per-task analysis: weighted
  cutout stacking (Pallas) followed by normalization and basic image
  statistics. One call = one task's μ(κ) in the live engine.
* :func:`model_eval_graph` — the batched §4.3 abstract-model evaluator
  used by the Figure 2 validation sweeps.

Python only runs at build time (``make artifacts``); the request path is
pure Rust + PJRT.
"""

import jax
import jax.numpy as jnp

from compile.kernels import model_eval as me
from compile.kernels import stacking


@jax.jit
def stack_pipeline(cutouts: jax.Array, weights: jax.Array):
    """Stack `cutouts` (N, H, W) with `weights` (N,), normalized.

    Returns (stacked_image (H, W), mean, peak) — the statistics the
    AstroPortal-style service reports per stacking request.
    """
    raw = stacking.stack(cutouts, weights)
    total = jnp.sum(weights)
    # Guard against an all-zero weight vector (empty stacking request).
    img = raw / jnp.maximum(total, jnp.finfo(raw.dtype).tiny)
    return img, jnp.mean(img), jnp.max(img)


@jax.jit
def model_eval_graph(k, cpus, mu, o, beta, inv_a, nu_pi, nu_tau, p_miss):
    """Batched abstract-model evaluation; see kernels/model_eval.py."""
    return me.model_eval(k, cpus, mu, o, beta, inv_a, nu_pi, nu_tau, p_miss)
