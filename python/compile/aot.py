"""AOT lowering: JAX graphs → HLO *text* artifacts for the Rust runtime.

HLO text — not ``lowered.compiler_ir("hlo")`` protos and not
``.serialize()`` — is the interchange format: jax ≥ 0.5 emits protos with
64-bit instruction ids that the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits:
  artifacts/stacking.hlo.txt     stack_pipeline(N=128, H=64, W=64)
  artifacts/model_eval.hlo.txt   model_eval_graph(B=64)
  artifacts/manifest.txt         name → file, shapes (parsed by Rust)
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

#: Fixed example shapes baked into the artifacts (the Rust side pads).
STACK_N, STACK_H, STACK_W = 128, 64, 64
MODEL_BATCH = 64


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (id-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stacking() -> str:
    spec = jax.ShapeDtypeStruct((STACK_N, STACK_H, STACK_W), jnp.float32)
    wspec = jax.ShapeDtypeStruct((STACK_N,), jnp.float32)
    return to_hlo_text(jax.jit(model.stack_pipeline).lower(spec, wspec))


def lower_model_eval() -> str:
    b = jax.ShapeDtypeStruct((MODEL_BATCH,), jnp.float32)
    return to_hlo_text(jax.jit(model.model_eval_graph).lower(*([b] * 9)))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    artifacts = {
        "stacking": (
            lower_stacking(),
            f"inputs=cutouts:f32[{STACK_N},{STACK_H},{STACK_W}],weights:f32[{STACK_N}] "
            f"outputs=image:f32[{STACK_H},{STACK_W}],mean:f32[],peak:f32[]",
        ),
        "model_eval": (
            lower_model_eval(),
            f"inputs=9x f32[{MODEL_BATCH}] "
            f"outputs=7x f32[{MODEL_BATCH}] (V,Y,W,E,S,omega,zeta)",
        ),
    }

    manifest_lines = []
    for name, (hlo, sig) in artifacts.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(hlo)
        manifest_lines.append(f"{name}\t{name}.hlo.txt\t{sig}")
        print(f"wrote {path} ({len(hlo)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as fh:
        fh.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
