#!/usr/bin/env python3
"""CI gate over perf_hotpath JSON snapshots — ratio metrics only.

Usage:
    bench_gate.py FRESH.json BASELINE.json
    bench_gate.py --self-test

Shared CI runners are too noisy for absolute-time assertions, so the gate
checks only quantities that noise cannot fake:

1. *Within-run speedups* (fresh snapshot only): the indexed sub-linear
   pickup must not be slower than the retained reference window scan
   (speedup >= 1.0 with tolerance), and the batched flow-net rerate must
   not do more per-event work than the per-event reference.
2. *Within-run maintenance work* (fresh snapshot only): the epoch-lazy
   pending-index maintenance must not do more per-entry work than the
   eager reference on the hot-file churn workload
   (pending/maintenance_ops <= pending/eager_maintenance_ops),
   select_notify must never recount holder overlap per call
   (notify/holder_recounts == 0 — the memoized-ranking tripwire), and the
   dead-hint purge path must stay live (pending/dead_hints_purged > 0 —
   the bench's leave-queue phase deterministically creates dead hints, so
   a zero means lazily-dropped candidates are leaking instead of being
   purged on encounter), and candidate-set recycling must stay live
   (pending/slab_reuse > 0 — the bench's leave/rejoin churn parks freed
   sets in the pool, so a zero means every re-registration allocates a
   fresh set and the slab grows without bound under provisioner churn).
3. *Sharded-router accounting* (fresh snapshot only): the K=4 bench
   fixture submits cross-shard pair tasks, so shard/cross_fetches must be
   > 0 (a zero means the router stopped rewriting GPFS misses into
   cross-shard peer fetches), shard/cross_fetches_per_task must stay
   <= 1.0 (every fixture task has at most ONE foreign-homed file, so on
   this fixture more than one rewrite per task means the router
   double-accounted transfers — the bound is fixture-scoped; a workload
   of tasks with several foreign-homed files could legitimately exceed
   it), and shard/router_events must be > 0.
3b. *Chaos-harness accounting* (fresh snapshot only): the bench's seeded
   chaos block must keep injecting faults (chaos/faults_injected > 0 — a
   zero means the fault schedule went vacuous and the robustness gate
   guards nothing) and the shadow-state oracle must stay silent
   (chaos/oracle_violations == 0 — any violation is a real invariant
   break, reproducible with `datadiff chaos --seed N`).
3c. *Scenario-library generation* (fresh snapshot only): the fixed-seed
   pass over all four workload families must keep producing tasks
   (workload/tasks_generated > 0) and dependency edges
   (workload/dep_edges > 0 — the pipeline family deterministically
   links stages, so a zero means arrival gating is vacuously dead);
   workload/dep_edges_per_task additionally rides the baseline drift
   rule below.
3d. *Model-predictive controller accounting* (fresh snapshot only): the
   bench's regime-shift pass must keep the §3 solver alive
   (model/solves > 0) and its 10x arrival surge must move the adopted
   fleet target (model/target_changes > 0 — a zero means the controller
   is frozen and `--allocation model` degenerates to a static fleet),
   and the K=4 one-sided-load fixture must shift provisioner quota
   between shards (model/shard_rebalances > 0 — a zero means the
   router's pressure-weighted apportionment went dead);
   model/deadband_holds is reported for visibility, and
   model/target_changes_per_decision rides the baseline drift rule
   below (a churn spike means the deadband stopped damping).
3e. *Live-engine accounting* (fresh snapshot only): the K=2 sharded
   live bench runs real worker pools behind the router, so every
   shard's pool must be staffed (live/workers_per_shard > 0 — the
   counter is the *minimum* pool peak across shards, so a zero means
   some shard never received a worker and its queue ran on borrowed
   capacity), cross-shard copies must fire (live/cross_fetches > 0)
   and move real bytes (live/cross_copy_bytes > 0 — a zero with
   nonzero fetches means the copy path stopped accounting transfer
   sizes).
3f. *Million-task scale drive* (fresh snapshot only): the arena/SoA
   scale group must run and stay within its allocation budget —
   scale/events_per_sec must be present and positive (a wall-clock
   throughput, reported but not compared across machines),
   scale/allocs_per_event (scratch-pool misses per handler event, a
   deterministic recycling-regression proxy) must stay below
   SCALE_ALLOC_RATE_MAX, and scale/peak_table_bytes must be positive
   (a zero means the arena tables report no footprint, i.e. the
   accounting went dead).
4. *Deterministic work counters* (fresh vs committed baseline): tasks
   inspected per pickup, boundary-cursor steps, flow rerates per event,
   pending maintenance ops per event, dead hints purged per event, notify
   memo hits per decision, cross-shard fetches per task. These are
   machine-independent, so drift beyond a generous tolerance means the
   algorithm regressed, not the runner. Skipped (with a warning) while
   the baseline still carries `"measured": false` — the bench job
   refreshes it one-shot on the next main push.

`--self-test` drives the gate against synthetic snapshots — one passing
pair, then one mutation per enforced rule, asserting each mutation is
caught. Runs as a CI step so the gate itself cannot rot silently.

Exit status 0 = pass, 1 = fail.
"""

import copy
import json
import math
import sys

# Generous: counters are deterministic but fixtures evolve; timing ratios
# within one run still wobble a little on loaded runners.
SPEEDUP_TOLERANCE = 0.90  # "indexed >= reference" may sag to 0.9x on noise
WORK_RATIO_TOLERANCE = 1.05  # batched work must stay <= 1.05x reference
COUNTER_DRIFT = 1.5  # fresh counter may drift to 1.5x baseline
# The scale drive recycles every effect Vec through the core's scratch
# pool, so allocs_per_event sits near 1e-5 (pool warm-up only). 0.05
# still passes a cold pool on the CI quick fixture; a recycling
# regression jumps straight to ~1.0 (one fresh Vec per event).
SCALE_ALLOC_RATE_MAX = 0.05


class GateFailure(Exception):
    """One enforced rule was violated."""


def fail(msg):
    raise GateFailure(msg)


def load(path):
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError) as e:
        fail(f"cannot read {path}: {e}")


def case_means(snapshot, group_name):
    for group in snapshot.get("groups", []):
        if group.get("name") == group_name:
            return {c["label"]: c.get("mean_s") for c in group.get("cases", [])}
    return {}


def finite(x):
    return isinstance(x, (int, float)) and math.isfinite(x) and x > 0


def run_gate(fresh, baseline):
    """Apply every enforced rule; raises GateFailure on the first hit."""
    groups = fresh.get("groups", [])
    if not groups:
        fail("fresh snapshot has no bench groups")
    for group in groups:
        if not group.get("cases"):
            fail(f"group `{group.get('name')}` has no cases")
        for case in group["cases"]:
            if not finite(case.get("mean_s")):
                fail(f"non-finite mean in `{group['name']}` / `{case.get('label')}`")

    counters = fresh.get("counters", {})

    # --- 1. indexed pickup vs reference window scan (within-run). -------
    indexed = case_means(fresh, "scheduler pick_tasks (64 nodes, warm index)")
    reference = case_means(fresh, "scheduler reference window scan (64 nodes, warm index)")
    for policy in ("max-compute-util", "good-cache-compute"):
        if policy not in indexed or policy not in reference:
            fail(f"missing scheduler case `{policy}` in fresh snapshot")
        speedup = reference[policy] / indexed[policy]
        print(f"bench-gate: indexed-vs-reference speedup [{policy}] = {speedup:.2f}x")
        if speedup < SPEEDUP_TOLERANCE:
            fail(
                f"indexed pickup slower than the reference scan for {policy}: "
                f"{speedup:.2f}x < {SPEEDUP_TOLERANCE}x"
            )

    # --- 2. batched vs reference flow rerate work (within-run). ---------
    for concurrency in (16, 128):
        for metric in ("rerates", "heap_updates"):
            b_key = f"flow/batched_{metric}_per_event@{concurrency}"
            r_key = f"flow/reference_{metric}_per_event@{concurrency}"
            if b_key not in counters or r_key not in counters:
                fail(f"missing flow counters {b_key}/{r_key}")
            ratio = counters[b_key] / max(counters[r_key], 1e-12)
            print(
                f"bench-gate: flow {metric}@{concurrency}: batched/reference = {ratio:.3f}"
            )
            if ratio > WORK_RATIO_TOLERANCE:
                fail(
                    f"batched flow {metric} exceeds the per-event reference at "
                    f"{concurrency} concurrent: ratio {ratio:.3f} > {WORK_RATIO_TOLERANCE}"
                )

    # --- 2b. lazy vs eager pending maintenance (within-run). ------------
    for key in (
        "pending/maintenance_ops",
        "pending/eager_maintenance_ops",
        "pending/maintenance_ops_per_event",
        "pending/eager_maintenance_ops_per_event",
        "pending/epoch_rebuilds",
        "pending/dead_hints_purged",
        "pending/dead_hints_purged_per_event",
        "pending/slab_reuse",
        "notify/holder_recounts",
    ):
        if key not in counters:
            fail(f"missing counter {key}")
    ratio = counters["pending/maintenance_ops"] / max(
        counters["pending/eager_maintenance_ops"], 1e-12
    )
    print(f"bench-gate: pending maintenance: lazy/eager = {ratio:.3f}")
    if ratio > WORK_RATIO_TOLERANCE:
        fail(
            "epoch-lazy pending maintenance exceeds the eager reference on the "
            f"hot-file workload: ratio {ratio:.3f} > {WORK_RATIO_TOLERANCE}"
        )
    recounts = counters["notify/holder_recounts"]
    print(f"bench-gate: notify holder recounts = {recounts:g}")
    if recounts != 0:
        fail(
            f"select_notify recounted holder overlap {recounts:g} time(s): the "
            "memoized head ranking has been bypassed"
        )
    purged = counters["pending/dead_hints_purged"]
    print(f"bench-gate: dead hints purged = {purged:g}")
    if purged <= 0:
        fail(
            "pending/dead_hints_purged is 0: the bench's leave-queue phase "
            "deterministically creates dead hints, so the purge-on-encounter "
            "path has stopped firing (lazily-dropped candidates are leaking)"
        )
    slab_reuse = counters["pending/slab_reuse"]
    print(f"bench-gate: pending slab reuse = {slab_reuse:g}")
    if slab_reuse <= 0:
        fail(
            "pending/slab_reuse is 0: the bench's leave/rejoin churn "
            "deterministically parks freed candidate sets in the pool, so "
            "re-registration has stopped recycling them (every rejoin "
            "allocates a fresh set)"
        )

    # --- 2c. sharded-router cross-fetch accounting (within-run). --------
    for key in (
        "shard/router_events",
        "shard/cross_fetches",
        "shard/cross_fetches_per_task",
    ):
        if key not in counters:
            fail(f"missing counter {key}")
    cross = counters["shard/cross_fetches"]
    per_task = counters["shard/cross_fetches_per_task"]
    print(
        f"bench-gate: shard cross fetches = {cross:g} "
        f"({per_task:.3f} per task, {counters['shard/router_events']:g} router events)"
    )
    if counters["shard/router_events"] <= 0:
        fail("shard/router_events is 0: the sharded bench fixture never ran")
    if cross <= 0:
        fail(
            "shard/cross_fetches is 0: the K=4 fixture's cross-shard pair tasks "
            "deterministically require peer-fetch rewrites, so the router has "
            "stopped rewriting GPFS misses into cross-shard fetches"
        )
    if per_task > 1.0:
        fail(
            f"shard/cross_fetches_per_task = {per_task:.3f} > 1.0: every "
            "fixture task has at most one foreign-homed file, so more than "
            "one rewrite per task on this fixture means the router is "
            "double-accounting cross-shard transfers"
        )

    # --- 2d. chaos-harness accounting (within-run). ---------------------
    for key in ("chaos/faults_injected", "chaos/oracle_violations"):
        if key not in counters:
            fail(f"missing counter {key}")
    faults = counters["chaos/faults_injected"]
    violations = counters["chaos/oracle_violations"]
    print(
        f"bench-gate: chaos faults injected = {faults:g}, "
        f"oracle violations = {violations:g}"
    )
    if faults <= 0:
        fail(
            "chaos/faults_injected is 0: the seeded fault schedule went "
            "vacuous, so the chaos gate no longer exercises the "
            "failure/replay path"
        )
    if violations != 0:
        fail(
            f"chaos/oracle_violations = {violations:g}: the shadow-state "
            "oracle caught real invariant breaks; reproduce with "
            "`datadiff chaos --seed N` using the seed in the bench output"
        )

    # --- 2e. scenario-library generation accounting (within-run). -------
    for key in (
        "workload/tasks_generated",
        "workload/dep_edges",
        "workload/dep_edges_per_task",
    ):
        if key not in counters:
            fail(f"missing counter {key}")
    tasks_generated = counters["workload/tasks_generated"]
    dep_edges = counters["workload/dep_edges"]
    print(
        f"bench-gate: scenario library generated {tasks_generated:g} tasks, "
        f"{dep_edges:g} dep edges"
    )
    if tasks_generated <= 0:
        fail(
            "workload/tasks_generated is 0: the scenario-library bench pass "
            "produced no tasks, so every family's generator is dead"
        )
    if dep_edges <= 0:
        fail(
            "workload/dep_edges is 0: the pipeline family deterministically "
            "links stage outputs to downstream inputs, so a zero means the "
            "dependency-gated arrival path is no longer exercised"
        )

    # --- 2f. model-predictive controller accounting (within-run). -------
    for key in (
        "model/solves",
        "model/target_changes",
        "model/deadband_holds",
        "model/target_changes_per_decision",
        "model/shard_rebalances",
    ):
        if key not in counters:
            fail(f"missing counter {key}")
    solves = counters["model/solves"]
    target_changes = counters["model/target_changes"]
    rebalances = counters["model/shard_rebalances"]
    print(
        f"bench-gate: model solves = {solves:g}, target changes = "
        f"{target_changes:g} (deadband holds = "
        f"{counters['model/deadband_holds']:g}), shard rebalances = "
        f"{rebalances:g}"
    )
    if solves <= 0:
        fail(
            "model/solves is 0: the model-predictive controller never ran "
            "its §3 solve, so `--allocation model` is not being exercised"
        )
    if target_changes <= 0:
        fail(
            "model/target_changes is 0: the bench's 10x arrival surge must "
            "move the adopted fleet target, so the controller is frozen "
            "(deadband stuck or solver ignoring its inputs)"
        )
    if rebalances <= 0:
        fail(
            "model/shard_rebalances is 0: the K=4 one-sided-load fixture "
            "deterministically concentrates pressure on one shard, so the "
            "router's pressure-weighted quota apportionment has gone dead"
        )

    # --- 2g. live-engine accounting (within-run). -----------------------
    for key in (
        "live/workers_per_shard",
        "live/cross_copy_bytes",
        "live/cross_fetches",
    ):
        if key not in counters:
            fail(f"missing counter {key}")
    live_pool = counters["live/workers_per_shard"]
    live_cross = counters["live/cross_fetches"]
    live_bytes = counters["live/cross_copy_bytes"]
    print(
        f"bench-gate: live pools (min per shard) = {live_pool:g}, "
        f"cross copies = {live_cross:g} moving {live_bytes:g} bytes"
    )
    if live_pool <= 0:
        fail(
            "live/workers_per_shard is 0: some router shard never received "
            "a live worker, so its queue can only drain through other "
            "shards' pools (per-shard pool staffing went dead)"
        )
    if live_cross <= 0:
        fail(
            "live/cross_fetches is 0: the K=2 live fixture's pair tasks "
            "deterministically chain a fetch of the other shard's cached "
            "file, so the live engine stopped enacting cross-shard copies"
        )
    if live_bytes <= 0:
        fail(
            "live/cross_copy_bytes is 0: cross-shard copies fired but moved "
            "no accounted bytes, so the worker-to-worker transfer "
            "accounting went dead"
        )

    # --- 2h. million-task scale-drive accounting (within-run). ----------
    for key in (
        "scale/events_per_sec",
        "scale/allocs_per_event",
        "scale/peak_table_bytes",
    ):
        if key not in counters:
            fail(f"missing counter {key}")
    events_per_sec = counters["scale/events_per_sec"]
    allocs_per_event = counters["scale/allocs_per_event"]
    peak_table_bytes = counters["scale/peak_table_bytes"]
    print(
        f"bench-gate: scale drive = {events_per_sec:g} events/s, "
        f"{allocs_per_event:g} allocs/event, peak tables = "
        f"{peak_table_bytes:g} bytes"
    )
    if events_per_sec <= 0:
        fail(
            "scale/events_per_sec is 0: the million-task drive processed no "
            "events, so the arena/SoA hot path was never exercised at scale"
        )
    if allocs_per_event > SCALE_ALLOC_RATE_MAX:
        fail(
            f"scale/allocs_per_event = {allocs_per_event:g} exceeds "
            f"{SCALE_ALLOC_RATE_MAX}: the effect path is allocating per "
            "event again (scratch-pool recycling regressed)"
        )
    if peak_table_bytes <= 0:
        fail(
            "scale/peak_table_bytes is 0: the arena tables report no "
            "footprint, so table_bytes() accounting went dead"
        )

    # --- 3. inspected-per-pickup sanity (within-run). -------------------
    for policy in ("max-compute-util", "good-cache-compute"):
        key = f"inspected_per_pickup/{policy}"
        if key not in counters:
            fail(f"missing counter {key}")
        # The 64-node fixture window is 6400; sub-linear means far below.
        if counters[key] > 640:
            fail(
                f"{key} = {counters[key]:.1f}: pickup cost is tracking the "
                "window again (sub-linear pickup regressed)"
            )

    # --- 4. counter drift vs the committed baseline. --------------------
    if not baseline.get("measured", False):
        print(
            "bench-gate: baseline not yet measured "
            "(`measured: false`) — skipping drift checks; the bench job "
            "refreshes it one-shot on the next main push"
        )
    else:
        # Only per-unit-of-work counters are machine-independent; raw
        # totals (boundary/queries, cold_seek_steps, ...) scale with the
        # wall-clock-sized iteration count Bench::iter picks, so a faster
        # runner would inflate them with no real regression.
        ratio_suffixes = (
            "per_query",
            "per_event",
            "per_pickup",
            "per_decision",
            "per_task",
        )
        base_counters = baseline.get("counters", {})
        checked = skipped = 0
        for key, base_value in base_counters.items():
            if not any(s in key for s in ratio_suffixes):
                skipped += 1
                continue
            if key not in counters or base_value is None or base_value <= 0:
                continue
            ratio = counters[key] / base_value
            checked += 1
            if ratio > COUNTER_DRIFT:
                fail(
                    f"counter `{key}` drifted {ratio:.2f}x above the baseline "
                    f"({counters[key]:.3f} vs {base_value:.3f})"
                )
        print(
            f"bench-gate: {checked} baseline ratio counters within {COUNTER_DRIFT}x "
            f"({skipped} machine-dependent totals skipped)"
        )


# ---------------------------------------------------------------------------
# Self-test: synthetic snapshots through every enforced rule.


def synthetic_fresh():
    """A minimal snapshot satisfying every rule the gate enforces."""
    counters = {
        "pending/maintenance_ops": 100.0,
        "pending/eager_maintenance_ops": 400.0,
        "pending/maintenance_ops_per_event": 0.05,
        "pending/eager_maintenance_ops_per_event": 0.2,
        "pending/epoch_rebuilds": 1.0,
        "pending/dead_hints_purged": 8.0,
        "pending/dead_hints_purged_per_event": 0.004,
        "pending/slab_reuse": 4.0,
        "notify/holder_recounts": 0.0,
        "notify/memo_builds": 2.0,
        "notify/memo_hits_per_decision": 0.9,
        "inspected_per_pickup/max-compute-util": 2.0,
        "inspected_per_pickup/good-cache-compute": 2.5,
        "shard/router_events": 500.0,
        "shard/cross_fetches": 96.0,
        "shard/cross_fetches_per_task": 0.75,
        "chaos/faults_injected": 64.0,
        "chaos/oracle_violations": 0.0,
        "chaos/faults_injected_per_run": 8.0,
        "workload/tasks_generated": 20_000.0,
        "workload/dep_edges": 4_000.0,
        "workload/dep_edges_per_task": 0.2,
        "model/solves": 120.0,
        "model/target_changes": 3.0,
        "model/deadband_holds": 10.0,
        "model/target_changes_per_decision": 0.025,
        "model/shard_rebalances": 4.0,
        "live/workers_per_shard": 1.0,
        "live/cross_copy_bytes": 8192.0,
        "live/cross_fetches": 2.0,
        "scale/events_per_sec": 2_000_000.0,
        "scale/allocs_per_event": 0.0001,
        "scale/peak_table_bytes": 5e7,
    }
    for concurrency in (16, 128):
        for metric in ("rerates", "heap_updates"):
            counters[f"flow/batched_{metric}_per_event@{concurrency}"] = 1.0
            counters[f"flow/reference_{metric}_per_event@{concurrency}"] = 1.0
    return {
        "schema": 2,
        "measured": True,
        "groups": [
            {
                "name": "scheduler pick_tasks (64 nodes, warm index)",
                "cases": [
                    {"label": "max-compute-util", "mean_s": 1e-5},
                    {"label": "good-cache-compute", "mean_s": 1e-5},
                ],
            },
            {
                "name": "scheduler reference window scan (64 nodes, warm index)",
                "cases": [
                    {"label": "max-compute-util", "mean_s": 1e-4},
                    {"label": "good-cache-compute", "mean_s": 1e-4},
                ],
            },
        ],
        "counters": counters,
    }


def self_test():
    """One passing pair, then one mutation per rule; each must be caught."""
    fresh = synthetic_fresh()
    baseline = copy.deepcopy(fresh)
    run_gate(fresh, baseline)  # must pass

    def mutated(label, mutate):
        snap = copy.deepcopy(fresh)
        mutate(snap)
        try:
            run_gate(snap, copy.deepcopy(baseline))
        except GateFailure as e:
            print(f"bench-gate self-test: `{label}` correctly rejected ({e})")
            return
        raise SystemExit(f"bench-gate self-test: `{label}` was NOT rejected")

    def slow_indexed(s):
        s["groups"][0]["cases"][0]["mean_s"] = 1e-3

    def nan_mean(s):
        s["groups"][0]["cases"][0]["mean_s"] = float("nan")

    def batched_regresses(s):
        s["counters"]["flow/batched_rerates_per_event@128"] = 2.0

    def drop_flow_counter(s):
        del s["counters"]["flow/reference_heap_updates_per_event@16"]

    def lazy_exceeds_eager(s):
        s["counters"]["pending/maintenance_ops"] = 500.0

    def holder_recount(s):
        s["counters"]["notify/holder_recounts"] = 1.0

    def dead_hint_leak(s):
        s["counters"]["pending/dead_hints_purged"] = 0.0

    def missing_dead_hint_counter(s):
        del s["counters"]["pending/dead_hints_purged_per_event"]

    def slab_pool_dead(s):
        s["counters"]["pending/slab_reuse"] = 0.0

    def window_scan_regression(s):
        s["counters"]["inspected_per_pickup/max-compute-util"] = 6400.0

    def counter_drift(s):
        s["counters"]["pending/dead_hints_purged_per_event"] = 0.004 * 2.0

    def missing_shard_counter(s):
        del s["counters"]["shard/cross_fetches_per_task"]

    def cross_fetch_path_dead(s):
        s["counters"]["shard/cross_fetches"] = 0.0

    def cross_fetch_double_accounted(s):
        s["counters"]["shard/cross_fetches_per_task"] = 1.5

    def shard_fixture_never_ran(s):
        s["counters"]["shard/router_events"] = 0.0

    def chaos_schedule_vacuous(s):
        s["counters"]["chaos/faults_injected"] = 0.0

    def chaos_oracle_tripped(s):
        s["counters"]["chaos/oracle_violations"] = 2.0

    def missing_chaos_counter(s):
        del s["counters"]["chaos/oracle_violations"]

    def scenario_generators_dead(s):
        s["counters"]["workload/tasks_generated"] = 0.0

    def dep_edges_vanished(s):
        s["counters"]["workload/dep_edges"] = 0.0

    def missing_workload_counter(s):
        del s["counters"]["workload/dep_edges_per_task"]

    def dep_edges_per_task_drifts(s):
        s["counters"]["workload/dep_edges_per_task"] = 0.2 * 2.0

    def model_solver_dead(s):
        s["counters"]["model/solves"] = 0.0

    def model_target_frozen(s):
        s["counters"]["model/target_changes"] = 0.0

    def shard_rebalancing_dead(s):
        s["counters"]["model/shard_rebalances"] = 0.0

    def missing_model_counter(s):
        del s["counters"]["model/deadband_holds"]

    def target_churn_drifts(s):
        s["counters"]["model/target_changes_per_decision"] = 0.025 * 2.0

    def missing_live_counter(s):
        del s["counters"]["live/cross_copy_bytes"]

    def live_pool_unstaffed(s):
        s["counters"]["live/workers_per_shard"] = 0.0

    def live_cross_copies_dead(s):
        s["counters"]["live/cross_fetches"] = 0.0

    def live_copy_bytes_unaccounted(s):
        s["counters"]["live/cross_copy_bytes"] = 0.0

    def missing_scale_counter(s):
        del s["counters"]["scale/peak_table_bytes"]

    def scale_drive_never_ran(s):
        s["counters"]["scale/events_per_sec"] = 0.0

    def scale_allocates_per_event(s):
        s["counters"]["scale/allocs_per_event"] = 1.0

    def table_accounting_dead(s):
        s["counters"]["scale/peak_table_bytes"] = 0.0

    cases = [
        ("indexed pickup slower than reference", slow_indexed),
        ("non-finite case mean", nan_mean),
        ("batched flow work regresses", batched_regresses),
        ("missing flow counter", drop_flow_counter),
        ("lazy maintenance exceeds eager", lazy_exceeds_eager),
        ("holder overlap recounted", holder_recount),
        ("dead-hint purge path dead", dead_hint_leak),
        ("missing dead-hint counter", missing_dead_hint_counter),
        ("slab pool recycling dead", slab_pool_dead),
        ("pickup tracks the window again", window_scan_regression),
        ("ratio counter drifts past baseline", counter_drift),
        ("missing shard counter", missing_shard_counter),
        ("cross-shard fetch path dead", cross_fetch_path_dead),
        ("cross-shard fetch double-accounted", cross_fetch_double_accounted),
        ("sharded fixture never ran", shard_fixture_never_ran),
        ("chaos fault schedule vacuous", chaos_schedule_vacuous),
        ("chaos oracle caught violations", chaos_oracle_tripped),
        ("missing chaos counter", missing_chaos_counter),
        ("scenario generators dead", scenario_generators_dead),
        ("pipeline dep edges vanished", dep_edges_vanished),
        ("missing workload counter", missing_workload_counter),
        ("dep edges per task drifts past baseline", dep_edges_per_task_drifts),
        ("model solver dead", model_solver_dead),
        ("model target frozen", model_target_frozen),
        ("shard quota rebalancing dead", shard_rebalancing_dead),
        ("missing model counter", missing_model_counter),
        ("target churn drifts past baseline", target_churn_drifts),
        ("missing live counter", missing_live_counter),
        ("live shard pool unstaffed", live_pool_unstaffed),
        ("live cross-shard copies dead", live_cross_copies_dead),
        ("live copy bytes unaccounted", live_copy_bytes_unaccounted),
        ("missing scale counter", missing_scale_counter),
        ("scale drive never ran", scale_drive_never_ran),
        ("scale drive allocates per event", scale_allocates_per_event),
        ("arena table accounting dead", table_accounting_dead),
    ]
    for label, mutate in cases:
        mutated(label, mutate)

    # An unmeasured baseline must skip drift checks (and therefore pass a
    # drifted counter) without tripping anything else.
    drifted = copy.deepcopy(fresh)
    drifted["counters"]["pending/dead_hints_purged_per_event"] = 0.004 * 2.0
    unmeasured = copy.deepcopy(baseline)
    unmeasured["measured"] = False
    run_gate(drifted, unmeasured)

    print(f"bench-gate: SELF-TEST PASS ({len(cases) + 2} scenarios)")


def main():
    if len(sys.argv) == 2 and sys.argv[1] == "--self-test":
        self_test()
        return
    if len(sys.argv) != 3:
        print("bench-gate: FAIL: usage: bench_gate.py FRESH.json BASELINE.json | --self-test")
        sys.exit(1)
    try:
        fresh = load(sys.argv[1])
        baseline = load(sys.argv[2])
        run_gate(fresh, baseline)
    except GateFailure as e:
        print(f"bench-gate: FAIL: {e}")
        sys.exit(1)
    print("bench-gate: PASS")


if __name__ == "__main__":
    main()
