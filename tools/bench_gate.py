#!/usr/bin/env python3
"""CI gate over perf_hotpath JSON snapshots — ratio metrics only.

Usage: bench_gate.py FRESH.json BASELINE.json

Shared CI runners are too noisy for absolute-time assertions, so the gate
checks only quantities that noise cannot fake:

1. *Within-run speedups* (fresh snapshot only): the indexed sub-linear
   pickup must not be slower than the retained reference window scan
   (speedup >= 1.0 with tolerance), and the batched flow-net rerate must
   not do more per-event work than the per-event reference.
2. *Within-run maintenance work* (fresh snapshot only): the epoch-lazy
   pending-index maintenance must not do more per-entry work than the
   eager reference on the hot-file churn workload
   (pending/maintenance_ops <= pending/eager_maintenance_ops), and
   select_notify must never recount holder overlap per call
   (notify/holder_recounts == 0 — the memoized-ranking tripwire).
3. *Deterministic work counters* (fresh vs committed baseline): tasks
   inspected per pickup, boundary-cursor steps, flow rerates per event,
   pending maintenance ops per event, notify memo hits per decision.
   These are machine-independent, so drift beyond a generous tolerance
   means the algorithm regressed, not the runner. Skipped (with a
   warning) while the baseline still carries `"measured": false` — the
   bench job refreshes it one-shot on the next main push.

Exit status 0 = pass, 1 = fail.
"""

import json
import math
import sys

# Generous: counters are deterministic but fixtures evolve; timing ratios
# within one run still wobble a little on loaded runners.
SPEEDUP_TOLERANCE = 0.90  # "indexed >= reference" may sag to 0.9x on noise
WORK_RATIO_TOLERANCE = 1.05  # batched work must stay <= 1.05x reference
COUNTER_DRIFT = 1.5  # fresh counter may drift to 1.5x baseline


def fail(msg):
    print(f"bench-gate: FAIL: {msg}")
    sys.exit(1)


def load(path):
    try:
        with open(path) as fh:
            return json.load(fh)
    except (OSError, ValueError) as e:
        fail(f"cannot read {path}: {e}")


def case_means(snapshot, group_name):
    for group in snapshot.get("groups", []):
        if group.get("name") == group_name:
            return {c["label"]: c.get("mean_s") for c in group.get("cases", [])}
    return {}


def finite(x):
    return isinstance(x, (int, float)) and math.isfinite(x) and x > 0


def main():
    if len(sys.argv) != 3:
        fail("usage: bench_gate.py FRESH.json BASELINE.json")
    fresh = load(sys.argv[1])
    baseline = load(sys.argv[2])

    groups = fresh.get("groups", [])
    if not groups:
        fail("fresh snapshot has no bench groups")
    for group in groups:
        if not group.get("cases"):
            fail(f"group `{group.get('name')}` has no cases")
        for case in group["cases"]:
            if not finite(case.get("mean_s")):
                fail(f"non-finite mean in `{group['name']}` / `{case.get('label')}`")

    counters = fresh.get("counters", {})

    # --- 1. indexed pickup vs reference window scan (within-run). -------
    indexed = case_means(fresh, "scheduler pick_tasks (64 nodes, warm index)")
    reference = case_means(fresh, "scheduler reference window scan (64 nodes, warm index)")
    for policy in ("max-compute-util", "good-cache-compute"):
        if policy not in indexed or policy not in reference:
            fail(f"missing scheduler case `{policy}` in fresh snapshot")
        speedup = reference[policy] / indexed[policy]
        print(f"bench-gate: indexed-vs-reference speedup [{policy}] = {speedup:.2f}x")
        if speedup < SPEEDUP_TOLERANCE:
            fail(
                f"indexed pickup slower than the reference scan for {policy}: "
                f"{speedup:.2f}x < {SPEEDUP_TOLERANCE}x"
            )

    # --- 2. batched vs reference flow rerate work (within-run). ---------
    for concurrency in (16, 128):
        for metric in ("rerates", "heap_updates"):
            b_key = f"flow/batched_{metric}_per_event@{concurrency}"
            r_key = f"flow/reference_{metric}_per_event@{concurrency}"
            if b_key not in counters or r_key not in counters:
                fail(f"missing flow counters {b_key}/{r_key}")
            ratio = counters[b_key] / max(counters[r_key], 1e-12)
            print(
                f"bench-gate: flow {metric}@{concurrency}: batched/reference = {ratio:.3f}"
            )
            if ratio > WORK_RATIO_TOLERANCE:
                fail(
                    f"batched flow {metric} exceeds the per-event reference at "
                    f"{concurrency} concurrent: ratio {ratio:.3f} > {WORK_RATIO_TOLERANCE}"
                )

    # --- 2b. lazy vs eager pending maintenance (within-run). ------------
    for key in (
        "pending/maintenance_ops",
        "pending/eager_maintenance_ops",
        "pending/maintenance_ops_per_event",
        "pending/eager_maintenance_ops_per_event",
        "pending/epoch_rebuilds",
        "notify/holder_recounts",
    ):
        if key not in counters:
            fail(f"missing counter {key}")
    ratio = counters["pending/maintenance_ops"] / max(
        counters["pending/eager_maintenance_ops"], 1e-12
    )
    print(f"bench-gate: pending maintenance: lazy/eager = {ratio:.3f}")
    if ratio > WORK_RATIO_TOLERANCE:
        fail(
            "epoch-lazy pending maintenance exceeds the eager reference on the "
            f"hot-file workload: ratio {ratio:.3f} > {WORK_RATIO_TOLERANCE}"
        )
    recounts = counters["notify/holder_recounts"]
    print(f"bench-gate: notify holder recounts = {recounts:g}")
    if recounts != 0:
        fail(
            f"select_notify recounted holder overlap {recounts:g} time(s): the "
            "memoized head ranking has been bypassed"
        )

    # --- 3. inspected-per-pickup sanity (within-run). -------------------
    for policy in ("max-compute-util", "good-cache-compute"):
        key = f"inspected_per_pickup/{policy}"
        if key not in counters:
            fail(f"missing counter {key}")
        # The 64-node fixture window is 6400; sub-linear means far below.
        if counters[key] > 640:
            fail(
                f"{key} = {counters[key]:.1f}: pickup cost is tracking the "
                "window again (sub-linear pickup regressed)"
            )

    # --- 4. counter drift vs the committed baseline. --------------------
    if not baseline.get("measured", False):
        print(
            "bench-gate: baseline not yet measured "
            "(`measured: false`) — skipping drift checks; the bench job "
            "refreshes it one-shot on the next main push"
        )
    else:
        # Only per-unit-of-work counters are machine-independent; raw
        # totals (boundary/queries, cold_seek_steps, ...) scale with the
        # wall-clock-sized iteration count Bench::iter picks, so a faster
        # runner would inflate them with no real regression.
        ratio_suffixes = ("per_query", "per_event", "per_pickup", "per_decision")
        base_counters = baseline.get("counters", {})
        checked = skipped = 0
        for key, base_value in base_counters.items():
            if not any(s in key for s in ratio_suffixes):
                skipped += 1
                continue
            if key not in counters or base_value is None or base_value <= 0:
                continue
            ratio = counters[key] / base_value
            checked += 1
            if ratio > COUNTER_DRIFT:
                fail(
                    f"counter `{key}` drifted {ratio:.2f}x above the baseline "
                    f"({counters[key]:.3f} vs {base_value:.3f})"
                )
        print(
            f"bench-gate: {checked} baseline ratio counters within {COUNTER_DRIFT}x "
            f"({skipped} machine-dependent totals skipped)"
        )

    print("bench-gate: PASS")


if __name__ == "__main__":
    main()
