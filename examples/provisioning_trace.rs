//! Provisioning-policy ablation: how allocation aggressiveness shapes
//! the node trace, CPU-hours, and response time.
//!
//! The paper's DRP allocates on wait-queue pressure through GRAM
//! (30–60 s latency) and releases idle nodes; Figure 13 shows DRP using
//! 17 CPU-hours where static provisioning burns 46 for the same speedup.
//! This example compares one-at-a-time / additive / multiplicative /
//! all-at-once allocation plus the static baseline on the same workload
//! and prints the per-100 s node trace.
//!
//!     cargo run --release --example provisioning_trace [--quick]

use datadiffusion::config::ExperimentConfig;
use datadiffusion::coordinator::provisioner::{AllocationPolicy, ProvisionerConfig};
use datadiffusion::experiments::run_summary_experiment;
use datadiffusion::report::{f, Table};

fn main() {
    datadiffusion::util::logger::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let scale = if quick { 10 } else { 1 };

    let variants: Vec<(&str, ProvisionerConfig)> = vec![
        (
            "one-at-a-time",
            ProvisionerConfig {
                allocation: AllocationPolicy::OneAtATime,
                ..ProvisionerConfig::default()
            },
        ),
        (
            "additive-8",
            ProvisionerConfig {
                allocation: AllocationPolicy::Additive(8),
                ..ProvisionerConfig::default()
            },
        ),
        (
            "multiplicative-2x",
            ProvisionerConfig {
                allocation: AllocationPolicy::Multiplicative(2.0),
                ..ProvisionerConfig::default()
            },
        ),
        (
            "all-at-once",
            ProvisionerConfig {
                allocation: AllocationPolicy::AllAtOnce,
                ..ProvisionerConfig::default()
            },
        ),
        ("static-64", ProvisionerConfig::static_nodes(64)),
    ];

    let mut summary = Table::new(
        "provisioning ablation (good-cache-compute, 4GB caches)",
        &["allocation", "WET(s)", "CPU-hrs", "avg-resp(s)", "peak-nodes"],
    );
    let mut traces: Vec<(String, Vec<u32>)> = Vec::new();

    for (name, prov) in variants {
        let mut cfg = ExperimentConfig::paper_fig(8).unwrap();
        cfg.name = format!("prov-{name}");
        cfg.provisioner = prov;
        cfg.workload.num_tasks /= scale;
        let r = run_summary_experiment(&cfg);
        let trace: Vec<u32> = r
            .ts
            .buckets()
            .iter()
            .step_by(100)
            .map(|b| b.nodes)
            .collect();
        let peak = r.ts.buckets().iter().map(|b| b.nodes).max().unwrap_or(0);
        summary.row(vec![
            name.into(),
            f(r.summary.workload_execution_time_s, 0),
            f(r.summary.cpu_time_hours, 1),
            f(r.summary.avg_response_time_s, 1),
            peak.to_string(),
        ]);
        traces.push((name.into(), trace));
    }
    summary.print();
    let _ = summary.write_csv("provisioning_ablation");

    // Node trace table (every 100 s).
    let max_len = traces.iter().map(|(_, t)| t.len()).max().unwrap_or(0);
    let mut headers = vec!["t(s)".to_string()];
    headers.extend(traces.iter().map(|(n, _)| n.clone()));
    let refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut trace_table = Table::new("provisioned nodes over time", &refs);
    for i in 0..max_len {
        let mut row = vec![(i * 100).to_string()];
        for (_, t) in &traces {
            row.push(t.get(i).map_or("-".into(), |n| n.to_string()));
        }
        trace_table.row(row);
    }
    trace_table.print();
    let _ = trace_table.write_csv("provisioning_trace");
}
