//! Policy sweep: dispatch policy × cache-eviction policy ablation.
//!
//! The paper runs all experiments with LRU and defers the eviction-policy
//! question to future work (§6); this example answers it on the Fig 5
//! configuration (1 GB caches — the thrashing regime, where eviction
//! choice matters most) and sweeps all five dispatch policies at 4 GB.
//!
//!     cargo run --release --example policy_sweep [--quick]

use datadiffusion::cache::EvictionPolicy;
use datadiffusion::config::ExperimentConfig;
use datadiffusion::coordinator::scheduler::DispatchPolicy;
use datadiffusion::experiments::run_summary_experiment;
use datadiffusion::report::{f, pct, Table};

fn main() {
    datadiffusion::util::logger::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let scale = if quick { 10 } else { 1 };

    // --- 1. Eviction ablation on the cache-thrashing configuration.
    let mut evict_table = Table::new(
        "eviction-policy ablation (good-cache-compute, 1GB caches — paper future work §6)",
        &["eviction", "WET(s)", "efficiency", "hit-local", "miss"],
    );
    for policy in [
        EvictionPolicy::Lru,
        EvictionPolicy::Lfu,
        EvictionPolicy::Fifo,
        EvictionPolicy::Random,
    ] {
        let mut cfg = ExperimentConfig::paper_fig(5).unwrap();
        cfg.name = format!("evict-{}", policy.name());
        cfg.cache.policy = policy;
        cfg.workload.num_tasks /= scale;
        let r = run_summary_experiment(&cfg);
        evict_table.row(vec![
            policy.name().into(),
            f(r.summary.workload_execution_time_s, 0),
            pct(r.summary.efficiency),
            pct(r.summary.hit_local_rate),
            pct(r.summary.miss_rate),
        ]);
    }
    evict_table.print();
    let _ = evict_table.write_csv("policy_sweep_eviction");

    // --- 2. Dispatch-policy sweep at 4 GB caches.
    let mut dispatch_table = Table::new(
        "dispatch-policy sweep (4GB caches)",
        &["policy", "WET(s)", "efficiency", "hit-local", "hit-global", "miss", "cpu-util"],
    );
    for policy in DispatchPolicy::ALL {
        let mut cfg = ExperimentConfig::paper_fig(8).unwrap();
        cfg.name = format!("dispatch-{policy}");
        cfg.scheduler.policy = policy;
        cfg.workload.num_tasks /= scale;
        let r = run_summary_experiment(&cfg);
        dispatch_table.row(vec![
            policy.name().into(),
            f(r.summary.workload_execution_time_s, 0),
            pct(r.summary.efficiency),
            pct(r.summary.hit_local_rate),
            pct(r.summary.hit_global_rate),
            pct(r.summary.miss_rate),
            pct(r.summary.avg_cpu_utilization),
        ]);
    }
    dispatch_table.print();
    let _ = dispatch_table.write_csv("policy_sweep_dispatch");
}
