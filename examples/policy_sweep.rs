//! Policy sweep: dispatch policy × cache-eviction policy ablation.
//!
//! The paper runs all experiments with LRU and defers the eviction-policy
//! question to future work (§6); this example answers it on the Fig 5
//! configuration (1 GB caches — the thrashing regime, where eviction
//! choice matters most) and sweeps all five dispatch policies at 4 GB.
//!
//! The configs and tables live in `experiments::sweeps` (the figure
//! registry runs the same sweeps in CI); this wrapper fans the nine
//! independent runs out across worker threads.
//!
//!     cargo run --release --example policy_sweep [--quick] [--jobs N]

use datadiffusion::experiments::{registry, sweeps};
use datadiffusion::util::par;

fn main() {
    datadiffusion::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let scale = if quick { 0.1 } else { 1.0 };
    let jobs = args
        .iter()
        .position(|a| a == "--jobs")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or_else(par::default_jobs);

    // Both sweeps share one fan-out; results come back in config order,
    // so the tables are identical for any job count.
    let evict_cfgs = sweeps::eviction_configs(scale);
    let n_evict = evict_cfgs.len();
    let mut cfgs = evict_cfgs;
    cfgs.extend(sweeps::dispatch_configs(scale));
    let mut results = registry::run_configs(cfgs, jobs);
    let dispatch_results = results.split_off(n_evict);

    let evict_table = sweeps::eviction_table(&results);
    evict_table.print();
    let _ = evict_table.write_csv("policy_sweep_eviction");

    let dispatch_table = sweeps::dispatch_table(&dispatch_results);
    dispatch_table.print();
    let _ = dispatch_table.write_csv("policy_sweep_dispatch");
}
