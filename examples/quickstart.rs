//! Quickstart: run one paper experiment end to end in the simulator and
//! print the paper's summary metrics.
//!
//!     cargo run --release --example quickstart [fig]
//!
//! `fig` is a figure number 4–10 (default 7: good-cache-compute with
//! 2 GB caches — the near-ideal configuration).

use datadiffusion::config::ExperimentConfig;
use datadiffusion::experiments::{self, summary_table, summary_view_table};

fn main() {
    datadiffusion::util::logger::init();
    let fig: u32 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(7);
    let cfg = ExperimentConfig::paper_fig(fig).unwrap_or_else(|| {
        eprintln!("unknown figure {fig} (expected 4-10)");
        std::process::exit(2);
    });

    println!(
        "experiment `{}`: policy {}, {} cache/node, ideal WET {:.0}s",
        cfg.name,
        cfg.scheduler.policy,
        datadiffusion::util::units::fmt_bytes(cfg.cache.capacity_bytes),
        cfg.ideal_wet_s()
    );
    let result = experiments::run_summary_experiment(&cfg);
    summary_view_table(&result, 120).print();
    summary_table(std::slice::from_ref(&result)).print();
    println!(
        "\nsimulated {} events in {:.1}s wall ({:.0} events/s)",
        result.events_processed,
        result.sim_wall_s,
        result.events_processed as f64 / result.sim_wall_s
    );
}
