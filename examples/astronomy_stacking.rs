//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! This is the system-composition proof (DESIGN.md): it generates a real
//! on-disk astronomy-style dataset (binary cutout stacks), then runs the
//! **live** data-diffusion engine — Rust coordinator, data-aware
//! scheduler, worker threads with real file caches — where each task's
//! compute is the **AOT-compiled JAX/Pallas stacking pipeline executed
//! via PJRT**. Python is not involved at any point of the run (artifacts
//! were built once by `make artifacts`).
//!
//!     make artifacts && cargo run --release --example astronomy_stacking
//!
//! Reports throughput, cache hit rates, provisioning behaviour, and
//! cross-checks one stacked image against a pure-Rust reference.

use datadiffusion::cache::{CacheConfig, EvictionPolicy};
use datadiffusion::coordinator::provisioner::AllocationPolicy;
use datadiffusion::coordinator::scheduler::DispatchPolicy;
use datadiffusion::ids::FileId;
use datadiffusion::live::{self, ComputeKind, LiveConfig, LiveTask};
use datadiffusion::runtime::{shapes, Artifacts};
use datadiffusion::util::prng::{Pcg64, Zipf};

/// Cutouts per object file (≤ the artifact's fixed batch).
const CUTOUTS_PER_FILE: usize = 64;
/// Distinct sky objects (files) in the dataset.
const NUM_OBJECTS: usize = 60;
/// Stacking requests (tasks); ~5 accesses per object → locality 5.
const NUM_TASKS: usize = 300;

fn main() {
    datadiffusion::util::logger::init();
    if let Err(e) = real_main() {
        eprintln!("astronomy_stacking failed: {e}");
        std::process::exit(1);
    }
}

/// Parse `--allocation one|add:N|mult:F|all` (the provisioner's
/// allocation policy, shared with `datadiff run` through the
/// coordinator core). Defaults to one worker per decision — the gentle
/// growth the live testbed used before the policy was surfaced.
fn parse_allocation() -> datadiffusion::Result<AllocationPolicy> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut it = args.iter();
    let mut alloc = AllocationPolicy::OneAtATime;
    while let Some(a) = it.next() {
        match a.as_str() {
            "--allocation" => {
                let v = it.next().ok_or_else(|| {
                    datadiffusion::Error::config("--allocation needs a value")
                })?;
                alloc = v
                    .parse::<AllocationPolicy>()
                    .map_err(datadiffusion::Error::config)?;
            }
            other => {
                return Err(datadiffusion::Error::config(format!(
                    "unexpected argument `{other}` (supported: --allocation one|add:N|mult:F|all)"
                )));
            }
        }
    }
    Ok(alloc)
}

fn real_main() -> datadiffusion::Result<()> {
    let allocation = parse_allocation()?;
    // --- 0. Verify the AOT artifacts load (fail fast with guidance).
    let artifacts = Artifacts::open_default()?;
    println!(
        "PJRT platform: {} | artifacts OK (stacking + model_eval)",
        artifacts.platform()
    );
    let stacker = artifacts.stacking()?;

    // --- 1. Generate the dataset: NUM_OBJECTS binary files, each
    // holding CUTOUTS_PER_FILE cutout frames + per-cutout weights.
    let root = std::env::temp_dir().join(format!("dd-astro-{}", std::process::id()));
    let store = root.join("persistent-store");
    std::fs::create_dir_all(&store)?;
    let frame = shapes::STACK_H * shapes::STACK_W;
    let mut rng = Pcg64::seeded(2008);
    let mut tasks: Vec<LiveTask> = Vec::new();
    println!(
        "generating {NUM_OBJECTS} object files × {CUTOUTS_PER_FILE} cutouts of {}×{} px …",
        shapes::STACK_H,
        shapes::STACK_W
    );
    for obj in 0..NUM_OBJECTS {
        let mut floats: Vec<f32> = Vec::with_capacity(CUTOUTS_PER_FILE * (frame + 1));
        for _ in 0..CUTOUTS_PER_FILE * frame {
            // Noisy sky; stacking raises SNR.
            floats.push((rng.next_f64() as f32) * 0.1);
        }
        for _ in 0..CUTOUTS_PER_FILE {
            floats.push(0.5 + (rng.next_f64() as f32)); // weights
        }
        let bytes: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(store.join(format!("object-{obj}.stack")), bytes)?;
    }
    // Task stream: zipf popularity over objects (hot objects get
    // re-stacked — the AstroPortal access pattern).
    let zipf = Zipf::new(NUM_OBJECTS, 0.9);
    for _ in 0..NUM_TASKS {
        let obj = zipf.sample(&mut rng);
        tasks.push(LiveTask::single(
            format!("object-{obj}.stack"),
            FileId(obj as u32),
        ));
    }

    // --- 2. Sanity-check the compute path once, against a Rust oracle.
    let sample = std::fs::read(store.join("object-0.stack"))?;
    let floats: Vec<f32> = sample
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let (cutouts, weights) = floats.split_at(CUTOUTS_PER_FILE * frame);
    let res = stacker.stack(cutouts, &weights[..CUTOUTS_PER_FILE])?;
    let total: f32 = weights[..CUTOUTS_PER_FILE].iter().sum();
    let mut want0 = 0.0f32;
    for c in 0..CUTOUTS_PER_FILE {
        want0 += weights[c] * cutouts[c * frame];
    }
    want0 /= total;
    assert!(
        (res.image[0] - want0).abs() < 1e-3,
        "PJRT stacking disagrees with reference: {} vs {want0}",
        res.image[0]
    );
    println!(
        "numerics check OK (pixel[0]: pjrt {:.6} vs rust {:.6}; mean {:.6})",
        res.image[0], want0, res.mean
    );

    // --- 3. Run the live data-diffusion engine with PJRT compute.
    let cfg = LiveConfig {
        initial_workers: 1,
        max_workers: 4,
        queue_tasks_per_worker: 8,
        allocation,
        policy: DispatchPolicy::GoodCacheCompute,
        cache: CacheConfig {
            // Each worker can cache ~1/2 of the dataset: diffusion matters.
            capacity_bytes: (NUM_OBJECTS as u64 / 2)
                * (frame + 1) as u64
                * CUTOUTS_PER_FILE as u64
                * 4,
            policy: EvictionPolicy::Lru,
        },
        persistent_dir: store.clone(),
        cache_root: root.join("caches"),
        compute: ComputeKind::Stacking,
        seed: 42,
        idle_release_s: 0.0,
        shards: 1,
        faults: live::LiveFaults::default(),
    };
    println!(
        "running {NUM_TASKS} stacking tasks through the live engine \
         (good-cache-compute, 1→{} workers, allocation {}) …",
        cfg.max_workers, cfg.allocation
    );
    let report = live::run(&cfg, &tasks)?;

    // --- 4. Report (the paper's metrics on the real run).
    let accesses = (report.hits_local + report.hits_global + report.misses).max(1) as f64;
    println!("\n== astronomy stacking: live data diffusion ==");
    println!("tasks completed      : {}", report.completed);
    println!("tasks failed         : {}", report.failed);
    println!("makespan             : {:.2?}", report.makespan);
    println!(
        "throughput           : {:.1} tasks/s, {:.1} MB/s moved",
        report.completed as f64 / report.makespan.as_secs_f64(),
        report.bytes_moved as f64 / 1e6 / report.makespan.as_secs_f64()
    );
    println!(
        "cache hits           : {:.1}% local, {:.1}% peer, {:.1}% miss",
        report.hits_local as f64 / accesses * 100.0,
        report.hits_global as f64 / accesses * 100.0,
        report.misses as f64 / accesses * 100.0
    );
    println!(
        "per task             : fetch {:.2?}, PJRT compute {:.2?}",
        report.avg_fetch, report.avg_compute
    );
    println!("peak workers (DRP)   : {}", report.peak_workers);

    assert_eq!(report.completed as usize, NUM_TASKS, "tasks lost");
    assert!(
        report.hits_local + report.hits_global > 0,
        "diffusion produced no cache hits"
    );
    let _ = std::fs::remove_dir_all(&root);
    println!("\nOK — three layers composed: Rust coordinator → HLO/PJRT → Pallas kernel");
    Ok(())
}
